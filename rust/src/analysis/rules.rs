//! The rule implementations behind [`RULE_TABLE`](crate::analysis::RULE_TABLE).
//!
//! Every rule is a pure function `fn(&Workspace) -> Vec<Finding>` over the
//! stripped source model ([`scan`](crate::analysis::scan)): no I/O, no
//! global state, so the fixture suite can run each rule against a
//! one-file synthetic workspace. Suppression is NOT applied here — the
//! driver ([`run`](crate::analysis::run)) matches raw findings against
//! the `flexlint::` allow annotations afterwards, so a rule never needs
//! to know about allows.

use super::scan::SourceFile;
use super::{Coverage, Finding, Workspace};

// ---------------------------------------------------------------------------
// Text helpers (shared by several rules).
// ---------------------------------------------------------------------------

fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Word-boundary substring search: `word` occurs in `text` with non-ident
/// characters (or text edges) on both sides.
fn contains_word(text: &str, word: &str) -> bool {
    let bytes = text.as_bytes();
    let mut from = 0;
    while let Some(p) = text[from..].find(word) {
        let at = from + p;
        let before_ok = at == 0 || !is_ident(bytes[at - 1]);
        let after = at + word.len();
        let after_ok = after >= bytes.len() || !is_ident(bytes[after]);
        if before_ok && after_ok {
            return true;
        }
        from = at + 1;
    }
    false
}

/// Skip whitespace (including newlines) from `i`; returns the next index.
fn skip_ws(bytes: &[u8], mut i: usize) -> usize {
    while i < bytes.len() && (bytes[i] as char).is_whitespace() {
        i += 1;
    }
    i
}

/// Given the index of an opening delimiter, return the index ONE PAST its
/// balanced closing partner (best-effort: returns `len` when unbalanced).
fn skip_balanced(bytes: &[u8], open: usize) -> usize {
    let (o, c) = match bytes[open] {
        b'(' => (b'(', b')'),
        b'[' => (b'[', b']'),
        b'{' => (b'{', b'}'),
        _ => return open + 1,
    };
    let mut depth = 0i32;
    let mut i = open;
    while i < bytes.len() {
        if bytes[i] == o {
            depth += 1;
        } else if bytes[i] == c {
            depth -= 1;
            if depth == 0 {
                return i + 1;
            }
        }
        i += 1;
    }
    bytes.len()
}

/// Remove ALL whitespace (place-expression normalization: `bufs[g * w]`
/// and `bufs[g*w]` must compare equal for the put-back check).
fn squash(text: &str) -> String {
    text.chars().filter(|c| !c.is_whitespace()).collect()
}

fn finding(f: &SourceFile, rule: &'static str, line: usize, message: String) -> Finding {
    Finding {
        rule,
        file: f.rel.clone(),
        line,
        excerpt: f.raw_line(line).to_string(),
        message,
    }
}

// ---------------------------------------------------------------------------
// Rule: nan-partial-cmp
// ---------------------------------------------------------------------------

/// `.partial_cmp(..)` chained into `.unwrap()`, `.expect(..)` or
/// `.unwrap_or(..Equal..)` — the float-comparator NaN panic/non-total-order
/// class PR 2 fixed in artopk/topk that keeps reappearing. The sanctioned
/// comparator is `tensor::nan_min_cmp`/`nan_min_cmp_f32`.
pub fn nan_partial_cmp(ws: &Workspace) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &ws.files {
        let code = &f.code;
        let bytes = code.as_bytes();
        let mut from = 0;
        while let Some(p) = code[from..].find(".partial_cmp") {
            let at = from + p;
            from = at + 1;
            let mut j = at + ".partial_cmp".len();
            if j < bytes.len() && is_ident(bytes[j]) {
                continue; // `.partial_cmp_something`
            }
            j = skip_ws(bytes, j);
            if j >= bytes.len() || bytes[j] != b'(' {
                continue;
            }
            let after_args = skip_balanced(bytes, j);
            let k = skip_ws(bytes, after_args);
            let rest = &code[k..];
            let bad = if rest.starts_with(".unwrap()") || rest.starts_with(".expect(") {
                true
            } else if rest.starts_with(".unwrap_or(") {
                let open = k + ".unwrap_or".len();
                let close = skip_balanced(bytes, open);
                code[open..close].contains("Equal")
            } else {
                false
            };
            if bad {
                out.push(finding(
                    f,
                    "nan-partial-cmp",
                    f.line_of(at),
                    "NaN-unsafe float comparator: route through tensor::nan_min_cmp / \
                     nan_min_cmp_f32 (the crate NaN total order) — unwrap panics on NaN, \
                     unwrap_or(Equal) is not transitive and can panic sort/select_nth"
                        .to_string(),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: unsanctioned-clock
// ---------------------------------------------------------------------------

/// Any `Instant::now()` — wall-clock reads are only honest inside the
/// billing-sanctioned hot paths (artopk, ag_exchange, util::bench), which
/// carry audited allow annotations. Everywhere else a clock read breaks
/// the DESIGN §7 `t_comp` contract (time must be measured INSIDE pool
/// tasks on the critical path, never on the coordinator).
pub fn unsanctioned_clock(ws: &Workspace) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &ws.files {
        let mut from = 0;
        while let Some(p) = f.code[from..].find("Instant::now") {
            let at = from + p;
            from = at + 1;
            out.push(finding(
                f,
                "unsanctioned-clock",
                f.line_of(at),
                "wall-clock read outside a billing-sanctioned module: t_comp must be \
                 measured inside pool tasks on the critical path (DESIGN.md §7); add an \
                 audited flexlint::allow if this site is genuinely billed"
                    .to_string(),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: shared-rng
// ---------------------------------------------------------------------------

/// Per-worker code paths (any `fn` with a `worker` parameter) must derive
/// randomness as a pure function of the worker id (`worker_rng` /
/// `worker_step_rng` style, i.e. the seed expression mentions `worker`).
/// Draws from a shared stateful rng (`self.*rng*`), from the epoch-bucket
/// rng, or from a fresh rng NOT keyed by the worker are order- or
/// identity-dependent — the PR 7 compute-jitter bug class, which broke
/// DESIGN §7 bitwise thread-invariance.
pub fn shared_rng(ws: &Workspace) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &ws.files {
        let bytes = f.code.as_bytes();
        for span in &f.fns {
            // Per-worker path = the PARAMETER LIST names a `worker`.
            let params = match span.header.find('(') {
                Some(p) => &span.header[p..],
                None => continue,
            };
            if !contains_word(params, "worker") {
                continue;
            }
            let body = &f.code[span.body_range.0..span.body_range.1];
            let base = span.body_range.0;

            // (a) shared stateful rng fields: `self.<ident containing rng>`.
            let mut from = 0;
            while let Some(p) = body[from..].find("self.") {
                let at = from + p;
                from = at + 1;
                let mut j = at + "self.".len();
                let start = j;
                while j < body.len() && is_ident(body.as_bytes()[j]) {
                    j += 1;
                }
                if body[start..j].to_ascii_lowercase().contains("rng") {
                    out.push(finding(
                        f,
                        "shared-rng",
                        f.line_of(base + at),
                        format!(
                            "draw from shared rng field `self.{}` in per-worker fn \
                             `{}`: derive a worker_rng/worker_step_rng instead \
                             (order-dependent draws break §7 thread-invariance)",
                            &body[start..j],
                            span.name
                        ),
                    ));
                }
            }

            // (b) epoch-bucket rng in a per-worker path.
            let mut from = 0;
            while let Some(p) = body[from..].find("bucket_rng(") {
                let at = from + p;
                from = at + 1;
                out.push(finding(
                    f,
                    "shared-rng",
                    f.line_of(base + at),
                    format!(
                        "bucket_rng (shared across workers) in per-worker fn `{}`: \
                         key the derivation by worker (worker_rng/worker_step_rng)",
                        span.name
                    ),
                ));
            }

            // (c) fresh rng whose seed expression ignores the worker id.
            let mut from = 0;
            while let Some(p) = body[from..].find("Rng::new(") {
                let at = from + p;
                from = at + 1;
                let open = base + at + "Rng::new".len();
                let close = skip_balanced(bytes, open);
                let args = &f.code[open..close];
                if !contains_word(args, "worker") {
                    out.push(finding(
                        f,
                        "shared-rng",
                        f.line_of(base + at),
                        format!(
                            "fresh Rng in per-worker fn `{}` not keyed by `worker`: \
                             identical streams across workers (or a stream keyed only \
                             by call order) — derive from (seed, worker[, step])",
                            span.name
                        ),
                    ));
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: registry-coverage
// ---------------------------------------------------------------------------

/// Every config-surface enum variant must be reachable from its registry
/// table (the PR 5 review drift class: a hardcoded name list silently
/// missing a new row), and registry names must be unique within a table.
pub fn registry_coverage(ws: &Workspace) -> Vec<Finding> {
    let mut out = Vec::new();
    for b in ws.bindings.enums {
        let Some(ef) = ws.file(b.enum_file) else {
            out.push(Finding {
                rule: "registry-coverage",
                file: b.enum_file.to_string(),
                line: 1,
                excerpt: String::new(),
                message: format!(
                    "registry binding broken: file `{}` (declaring enum {}) not in \
                     the scan root",
                    b.enum_file, b.enum_name
                ),
            });
            continue;
        };
        let Some(variants) = enum_variants(ef, b.enum_name) else {
            out.push(finding(
                ef,
                "registry-coverage",
                1,
                format!("registry binding broken: `enum {}` not found", b.enum_name),
            ));
            continue;
        };
        // Collect the coverage text: the table initializer span, or the
        // concatenated bodies of the named fns across the workspace.
        let covered = |variant: &str| -> bool {
            let token = format!("{}::{}", b.enum_name, variant);
            match b.coverage {
                Coverage::TableSpan { table, file } => ws
                    .file(file)
                    .and_then(|tf| table_span(tf, table).map(|(s, e)| (tf, s, e)))
                    .map_or(false, |(tf, s, e)| contains_word(&tf.code[s..e], &token)),
                Coverage::FnBodies { fns } => ws.files.iter().any(|f| {
                    f.fns.iter().any(|span| {
                        fns.contains(&span.name.as_str())
                            && contains_word(
                                &f.code[span.body_range.0..span.body_range.1],
                                &token,
                            )
                    })
                }),
            }
        };
        // A broken table binding should fail loudly ONCE, not once per
        // variant.
        if let Coverage::TableSpan { table, file } = b.coverage {
            let ok = ws.file(file).and_then(|tf| table_span(tf, table)).is_some();
            if !ok {
                out.push(Finding {
                    rule: "registry-coverage",
                    file: file.to_string(),
                    line: 1,
                    excerpt: String::new(),
                    message: format!(
                        "registry binding broken: table `{table}` not found in `{file}`"
                    ),
                });
                continue;
            }
        }
        for (variant, line) in &variants {
            if b.exempt.contains(&variant.as_str()) {
                continue;
            }
            if !covered(variant) {
                out.push(finding(
                    ef,
                    "registry-coverage",
                    *line,
                    format!(
                        "enum variant {}::{} is not reachable from its registry ({}) — \
                         add the table row (the config/CLI surface reads ONLY the table)",
                        b.enum_name,
                        variant,
                        match b.coverage {
                            Coverage::TableSpan { table, .. } => table,
                            Coverage::FnBodies { .. } => "kind() impls",
                        }
                    ),
                ));
            }
        }
    }
    // Duplicate-name detection within the string-keyed tables.
    for t in ws.bindings.tables {
        let Some(tf) = ws.file(t.file) else { continue };
        let Some((s, e)) = table_span(tf, t.table) else {
            out.push(Finding {
                rule: "registry-coverage",
                file: t.file.to_string(),
                line: 1,
                excerpt: String::new(),
                message: format!(
                    "registry binding broken: table `{}` not found in `{}`",
                    t.table, t.file
                ),
            });
            continue;
        };
        let mut seen: Vec<(String, usize)> = Vec::new();
        for (name, off) in table_names(&tf.nocomment[s..e]) {
            let line = tf.line_of(s + off);
            if let Some((_, first)) = seen.iter().find(|(n, _)| *n == name) {
                out.push(finding(
                    tf,
                    "registry-coverage",
                    line,
                    format!(
                        "duplicate registry name \"{}\" in {} (first at line {}): \
                         parse() resolves only the first row",
                        name, t.table, first
                    ),
                ));
            } else {
                seen.push((name, line));
            }
        }
    }
    out
}

/// Variants of `enum <name>` in `file` as `(ident, 1-indexed line)`.
fn enum_variants(f: &SourceFile, name: &str) -> Option<Vec<(String, usize)>> {
    let pat = format!("enum {name}");
    let bytes = f.code.as_bytes();
    let mut from = 0;
    let at = loop {
        let p = f.code[from..].find(&pat)?;
        let at = from + p;
        from = at + 1;
        let after = at + pat.len();
        let before_ok = at == 0 || !is_ident(bytes[at.saturating_sub(1)]);
        let after_ok = after >= bytes.len() || !is_ident(bytes[after]);
        if before_ok && after_ok {
            break at;
        }
    };
    let open = at + f.code[at..].find('{')?;
    let close = skip_balanced(bytes, open) - 1;
    let body = &f.code[open + 1..close];
    let mut vars = Vec::new();
    let mut depth = 0i32;
    let mut expecting = true;
    let mut i = 0;
    let bb = body.as_bytes();
    while i < bb.len() {
        match bb[i] {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' | b'}' => depth -= 1,
            b',' if depth == 0 => expecting = true,
            c if depth == 0 && expecting && is_ident(c) && !c.is_ascii_digit() => {
                let start = i;
                while i < bb.len() && is_ident(bb[i]) {
                    i += 1;
                }
                vars.push((body[start..i].to_string(), f.line_of(open + 1 + start)));
                expecting = false;
                continue;
            }
            _ => {}
        }
        i += 1;
    }
    Some(vars)
}

/// Byte range (in the file's stripped text) of the `[...]` initializer of
/// `const`/`static` item `table`.
fn table_span(f: &SourceFile, table: &str) -> Option<(usize, usize)> {
    let bytes = f.code.as_bytes();
    for kw in ["const ", "static "] {
        let mut from = 0;
        while let Some(p) = f.code[from..].find(kw) {
            let at = from + p;
            from = at + 1;
            if at > 0 && is_ident(bytes[at - 1]) {
                continue; // e.g. `some_const ` — not the keyword
            }
            let rest = skip_ws(bytes, at + kw.len());
            if !f.code[rest..].starts_with(table)
                || is_ident(*bytes.get(rest + table.len()).unwrap_or(&b' '))
            {
                continue;
            }
            let eq = at + f.code[at..].find('=')?;
            let open = eq + f.code[eq..].find('[')?;
            let close = skip_balanced(bytes, open) - 1;
            return Some((open + 1, close));
        }
    }
    None
}

/// Registry names inside a table initializer span (the `nocomment` rep,
/// strings intact): string literals that either follow a `name:` field or
/// open a depth-1 tuple element. Returns `(name, byte offset in span)`.
fn table_names(span: &str) -> Vec<(String, usize)> {
    let bytes = span.as_bytes();
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut awaiting_tuple = false;
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'(' => {
                depth += 1;
                if depth == 1 {
                    awaiting_tuple = true;
                }
            }
            b'[' | b'{' => depth += 1,
            b')' | b']' | b'}' => depth -= 1,
            b'"' => {
                let start = i + 1;
                let mut j = start;
                while j < bytes.len() && bytes[j] != b'"' {
                    if bytes[j] == b'\\' {
                        j += 1;
                    }
                    j += 1;
                }
                let lit = span[start..j.min(span.len())].to_string();
                let is_name_field = {
                    let before = span[..i].trim_end();
                    before.ends_with("name:")
                };
                if (awaiting_tuple && depth == 1) || is_name_field {
                    out.push((lit, i));
                }
                awaiting_tuple = false;
                i = j + 1;
                continue;
            }
            _ => {}
        }
        i += 1;
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: release-silent-assert
// ---------------------------------------------------------------------------

/// A bool-form `debug_assert!` whose condition is an ordering comparison,
/// in a function with NO release-path fallback: release builds skip the
/// assert and run the unguarded arithmetic on garbage (the
/// `VirtualClock::advance` backwards-clock class, fixed in PR 4 by
/// pairing the assert with `.max(0.0)`).
pub fn release_silent_assert(ws: &Workspace) -> Vec<Finding> {
    const MARKERS: &[&str] = &[
        ".max(",
        ".min(",
        ".clamp(",
        "panic!(",
        "bail!(",
        "unreachable!(",
        "return Err",
        "cfg!(debug_assertions)",
    ];
    let mut out = Vec::new();
    for f in &ws.files {
        let bytes = f.code.as_bytes();
        for span in &f.fns {
            let body = &f.code[span.body_range.0..span.body_range.1];
            let base = span.body_range.0;
            let mut from = 0;
            while let Some(p) = body[from..].find("debug_assert!(") {
                let at = from + p;
                from = at + 1;
                let open = base + at + "debug_assert!".len();
                let close = skip_balanced(bytes, open);
                let args = &f.code[open + 1..close.saturating_sub(1)];
                let cond = first_macro_arg(args);
                if !has_ordering_cmp(cond) {
                    continue;
                }
                let guarded = MARKERS.iter().any(|m| body.contains(m))
                    || has_plain_assert(body);
                if !guarded {
                    out.push(finding(
                        f,
                        "release-silent-assert",
                        f.line_of(base + at),
                        format!(
                            "debug_assert! guards an ordering invariant in `{}` but \
                             release builds skip it with no fallback (.max/.min/.clamp/\
                             assert!/panic path): the unguarded arithmetic runs on \
                             out-of-range input silently",
                            span.name
                        ),
                    ));
                }
            }
        }
    }
    out
}

/// The condition (first macro argument, up to a top-level comma).
fn first_macro_arg(args: &str) -> &str {
    let bytes = args.as_bytes();
    let mut depth = 0i32;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' | b'}' => depth -= 1,
            b',' if depth == 0 => return &args[..i],
            _ => {}
        }
    }
    args
}

/// Does `cond` contain an ordering comparison (`<`, `>`, `<=`, `>=`)?
/// Arrows (`->`, `=>`), shifts (`<<`, `>>`) and turbofish (`::<`) are
/// excluded; `==`/`!=` are equality, not ordering, and never match.
fn has_ordering_cmp(cond: &str) -> bool {
    let b = cond.as_bytes();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == b'<' || c == b'>' {
            let prev = if i == 0 { b' ' } else { b[i - 1] };
            let next = *b.get(i + 1).unwrap_or(&b' ');
            if next == c {
                i += 2; // shift
                continue;
            }
            if c == b'>' && (prev == b'-' || prev == b'=') {
                i += 1; // arrow
                continue;
            }
            if c == b'<' && prev == b':' {
                i += 1; // turbofish
                continue;
            }
            return true;
        }
        i += 1;
    }
    false
}

/// A plain `assert!(` (not `debug_assert!(`) anywhere in the body.
fn has_plain_assert(body: &str) -> bool {
    let mut from = 0;
    while let Some(p) = body[from..].find("assert!(") {
        let at = from + p;
        from = at + 1;
        if !body[..at].ends_with("debug_") {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Rule: take-without-putback
// ---------------------------------------------------------------------------

/// `mem::take` (or a `mem::swap` against a freshly-made empty value — a
/// disguised take) on a place with no restoring assignment/swap later in
/// the same function. The taken arena lane survives as an EMPTY Vec, so
/// the next step silently reallocates (or computes on nothing) — the PR 6
/// AG-lane hazard that the take/put-back dance in `ag_exchange` exists to
/// prevent.
pub fn take_without_putback(ws: &Workspace) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &ws.files {
        let bytes = f.code.as_bytes();
        for span in &f.fns {
            let body = &f.code[span.body_range.0..span.body_range.1];
            let base = span.body_range.0;

            // `report_at`/`rest_from` are BODY-relative offsets: where to
            // attribute the finding, and where the put-back search starts
            // (just past the take call, so the call's own text never
            // satisfies it).
            let mut check = |report_at: usize, rest_from: usize, place_raw: &str, what: &str| {
                let mut t = place_raw.trim();
                while let Some(r) = t.strip_prefix('&') {
                    t = r.trim_start();
                }
                if let Some(r) = t.strip_prefix("mut ") {
                    t = r.trim_start();
                }
                let place = squash(t);
                if place.is_empty() {
                    return;
                }
                let rest = squash(&body[rest_from..]);
                if !restored(&rest, &place) {
                    out.push(finding(
                        f,
                        "take-without-putback",
                        f.line_of(base + report_at),
                        format!(
                            "{what} of `{place}` in `{}` with no put-back in the same \
                             function (no later `{place} = ..`, swap or replace): the \
                             lane is left empty and the arena contract breaks",
                            span.name
                        ),
                    ));
                }
            };

            // mem::take(&mut PLACE)
            let mut from = 0;
            while let Some(p) = body[from..].find("mem::take(") {
                let at = from + p;
                from = at + 1;
                let open = base + at + "mem::take".len();
                let close = skip_balanced(bytes, open);
                let args = &f.code[open + 1..close.saturating_sub(1)];
                check(at, close - base, args, "mem::take");
            }

            // mem::swap(a, b) where one side is a freshly-made empty value.
            let mut from = 0;
            while let Some(p) = body[from..].find("mem::swap(") {
                let at = from + p;
                from = at + 1;
                let open = base + at + "mem::swap".len();
                let close = skip_balanced(bytes, open);
                let args = &f.code[open + 1..close.saturating_sub(1)];
                let (a, b) = split_two_args(args);
                let disguised = |s: &str| {
                    let s = squash(s);
                    s.contains("Vec::new()")
                        || s.contains("String::new()")
                        || s.contains("::default()")
                        || s.contains("mem::take")
                };
                let victim = if disguised(a) && !disguised(b) {
                    Some(b)
                } else if disguised(b) && !disguised(a) {
                    Some(a)
                } else {
                    None
                };
                if let Some(v) = victim {
                    check(at, close - base, v, "disguised take (swap with empty)");
                }
            }
        }
    }
    out
}

/// Split a two-argument list at its top-level comma.
fn split_two_args(args: &str) -> (&str, &str) {
    let bytes = args.as_bytes();
    let mut depth = 0i32;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' | b'}' => depth -= 1,
            b',' if depth == 0 => return (&args[..i], &args[i + 1..]),
            _ => {}
        }
    }
    (args, "")
}

/// Does the (whitespace-squashed) tail of the function restore `place`?
/// Restores: `place=` (not `==`), or a later `mem::swap`/`mem::replace`
/// mentioning the place.
fn restored(rest: &str, place: &str) -> bool {
    let bytes = rest.as_bytes();
    let mut from = 0;
    while let Some(p) = rest[from..].find(place) {
        let at = from + p;
        from = at + 1;
        let before_ok = at == 0 || !is_ident(bytes[at - 1]);
        let after = at + place.len();
        if !before_ok {
            continue;
        }
        if bytes.get(after) == Some(&b'=') && bytes.get(after + 1) != Some(&b'=') {
            return true;
        }
    }
    for re in ["mem::swap(", "mem::replace("] {
        let mut from = 0;
        while let Some(p) = rest[from..].find(re) {
            let at = from + p;
            from = at + 1;
            let open = at + re.len() - 1;
            let close = skip_balanced(bytes, open);
            if rest[open..close].contains(place) {
                return true;
            }
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Rule: hot-loop-outside-kernels
// ---------------------------------------------------------------------------

/// Files on the compression hot path, where every inner loop must route
/// through `tensor::kernels` (DESIGN.md §7 "Kernel layer"). Directory
/// entries (trailing `/`) match by prefix, the rest exactly. `fixture.rs`
/// is in the set so the rule's own fixtures exercise it; the kernel home
/// itself is exempt — its chunked bodies and in-test verbatim scalar
/// references are the sanctioned implementations.
const KERNEL_AUDITED: &[&str] = &["compress/", "tensor/", "artopk.rs", "fixture.rs"];
const KERNEL_EXEMPT: &[&str] = &["tensor/kernels.rs"];

fn kernel_audited(rel: &str) -> bool {
    if KERNEL_EXEMPT.contains(&rel) {
        return false;
    }
    KERNEL_AUDITED.iter().any(|p| {
        if let Some(dir) = p.strip_suffix('/') {
            rel.starts_with(dir) && rel.as_bytes().get(dir.len()) == Some(&b'/')
        } else {
            rel == *p
        }
    })
}

/// Scalar hot loops in the audited hot files (`compress/`, `tensor/`,
/// `artopk.rs`) that bypass `tensor::kernels`:
///
/// * `.map(...).sum()` / `.sum::<..>()` — a sequential iterator reduction
///   where the lane-split kernels (`sq_norm_lanes`, `dot_lanes`,
///   `sq_norm_gather_lanes`) are the crate policy;
/// * `x[i as usize] = 0.0` — a manual index-zeroing store, the
///   `kernels::scatter_zero` pattern written by hand.
///
/// Verbatim scalar references inside kernel pin tests carry audited
/// allows — the reason is mandatory, so every bypass is on the record.
pub fn hot_loop_outside_kernels(ws: &Workspace) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &ws.files {
        if !kernel_audited(&f.rel) {
            continue;
        }
        let code = &f.code;
        let bytes = code.as_bytes();

        // Pattern (a): `.map( ... ).sum()` — chained sequential reduction.
        let mut from = 0;
        while let Some(p) = code[from..].find(".map") {
            let at = from + p;
            from = at + 1;
            let mut j = at + ".map".len();
            if j < bytes.len() && is_ident(bytes[j]) {
                continue; // `.map_while` etc.
            }
            j = skip_ws(bytes, j);
            if j >= bytes.len() || bytes[j] != b'(' {
                continue;
            }
            let after_args = skip_balanced(bytes, j);
            let k = skip_ws(bytes, after_args);
            let rest = &code[k..];
            if rest.starts_with(".sum()") || rest.starts_with(".sum::<") {
                out.push(finding(
                    f,
                    "hot-loop-outside-kernels",
                    f.line_of(at),
                    "sequential .map(..).sum() reduction on the hot path — route \
                     through tensor::kernels (sq_norm_lanes / dot_lanes / \
                     sq_norm_gather_lanes), the crate's lane-split reduction policy"
                        .to_string(),
                ));
            }
        }

        // Pattern (b): manual `x[i as usize] = 0.0` zeroing store.
        for (ln, line) in code.lines().enumerate() {
            if squash(line).contains("asusize]=0.0") {
                out.push(finding(
                    f,
                    "hot-loop-outside-kernels",
                    ln + 1,
                    "manual index-zeroing store on the hot path — use \
                     kernels::scatter_zero (the sorted-index residual-zero kernel)"
                        .to_string(),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: malformed-allow
// ---------------------------------------------------------------------------

/// Suppressions are themselves audited: a bare allow (no `: reason`), an
/// allow with no `(rule)`, or an allow naming a rule that is not in
/// `RULE_TABLE` is a finding — so suppressions can never silently rot.
pub fn malformed_allow(ws: &Workspace) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &ws.files {
        for a in &f.allows {
            let msg = if a.rule.is_empty() {
                Some("flexlint::allow without a (rule): name the rule being suppressed".to_string())
            } else if !super::RULE_TABLE.iter().any(|r| r.name == a.rule) {
                Some(format!(
                    "flexlint::allow names unknown rule `{}` (valid: {})",
                    a.rule,
                    super::rule_names().collect::<Vec<_>>().join(", ")
                ))
            } else if a.reason.is_none() {
                Some(format!(
                    "bare flexlint::allow({}) — the audit reason after `:` is mandatory",
                    a.rule
                ))
            } else {
                None
            };
            if let Some(message) = msg {
                out.push(finding(f, "malformed-allow", a.line, message));
            }
        }
    }
    out
}
