//! Trace-driven network environments, end to end (DESIGN.md §9):
//!
//! 1. round-trip smoke check: write a 3-phase trace, load it back, assert
//!    `link_at` replays the written samples exactly (run by
//!    scripts/verify.sh),
//! 2. replay the shipped measured trace (`examples/traces/c2_measured.csv`)
//!    and print the sampled conditions,
//! 3. train a short flexible run on it via
//!    `Session::builder().network(TraceModel::load(..)?)`,
//! 4. print the scenario-registry sweep (`experiments::scenario_rows`).
//!
//!     cargo run --release --example trace_replay -- [--trace <path>]

use anyhow::Result;
use flexcomm::coordinator::session::Session;
use flexcomm::coordinator::trainer::Strategy;
use flexcomm::coordinator::worker::ComputeModel;
use flexcomm::experiments::print_scenario_sweep;
use flexcomm::netsim::model::NetworkModel;
use flexcomm::netsim::trace::{TraceModel, TracePoint};
use flexcomm::runtime::HostMlp;
use flexcomm::util::cli::Args;
use flexcomm::util::table::Table;

fn round_trip_smoke() -> Result<()> {
    let original = TraceModel::from_points(
        "smoke",
        vec![
            TracePoint { epoch: 0.0, alpha_ms: 1.25, bw_gbps: 23.7 },
            TracePoint { epoch: 7.5, alpha_ms: 41.0, bw_gbps: 1.3 },
            TracePoint { epoch: 19.0, alpha_ms: 9.9, bw_gbps: 11.2 },
        ],
    )?;
    let path = std::env::temp_dir().join("flexcomm_trace_replay_smoke.csv");
    let path = path.to_str().expect("utf-8 temp path");
    original.save_csv(path)?;
    let loaded = TraceModel::load(path)?;
    assert_eq!(
        loaded.points(),
        original.points(),
        "write -> load must replay the exact samples"
    );
    for epoch in [0.0, 5.0, 7.5, 12.0, 19.0, 100.0] {
        assert_eq!(
            loaded.link_at(epoch),
            original.link_at(epoch),
            "link_at({epoch}) must match after the round trip"
        );
    }
    let _ = std::fs::remove_file(path);
    println!("trace round-trip: OK (3 phases, write -> load -> link_at identical)");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    round_trip_smoke()?;

    let path = args.str_or("trace", "examples/traces/c2_measured.csv");
    let trace = TraceModel::load(&path)?;
    println!("\nloaded {} -> {}", path, trace.describe());
    let mut t = Table::new(["epoch", "alpha (ms)", "bandwidth (Gbps)"]);
    for p in trace.points() {
        t.row([
            format!("{:.0}+", p.epoch),
            format!("{:.1}", p.alpha_ms),
            format!("{:.1}", p.bw_gbps),
        ]);
    }
    t.print();

    // A short flexible run driven by the measured trace: the Eqn 5
    // selector now reacts to the recording instead of a synthetic preset.
    let steps = args.u64_or("steps", 150)?;
    let report = Session::builder()
        .workers(4)
        .steps(steps)
        .steps_per_epoch((steps / 50).max(1))
        .strategy(Strategy::parse("flexible")?)
        .static_cr(0.05)
        .network(trace)
        .compute(ComputeModel::fixed(0.005))
        .seed(7)
        .source(Box::new(HostMlp::default_preset(7)))
        .build()?
        .run();
    let collectives: std::collections::BTreeSet<&str> =
        report.metrics.collectives_used().iter().map(|c| c.name()).collect();
    println!(
        "\ntrained {} steps on `{}`: best acc {:.1}%, collectives used: {:?}",
        report.steps,
        report.network,
        report.best_accuracy().unwrap_or(f64::NAN) * 100.0,
        collectives
    );

    println!("\nscenario registry sweep (ResNet50 bytes, N=8, CR 0.01):");
    print_scenario_sweep(50.0, 4.0 * 25.6e6, 8, 0.01);
    Ok(())
}
