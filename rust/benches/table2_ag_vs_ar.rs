//! Table II: Topk compression + communication cost of AG at CR {0.1,
//! 0.001} vs Ring-AR on uncompressed data, for 1e8 and 1e9-parameter
//! tensors across the paper's (α, 1/β) grid.
//!
//! Compression time is MEASURED on a real heavy-tailed gradient tensor
//! (quickselect Top-k, this host); communication time comes from the α-β
//! model the unit tests pin to the collective implementations.
//!
//!     cargo bench --bench table2_ag_vs_ar
//!     FLEXCOMM_BENCH_FAST=1 cargo bench ...   (CI quick mode)

use flexcomm::compress::{k_for, Compressor, TopK};
use flexcomm::experiments::{self, GPU_COMPRESS_SPEEDUP};
use flexcomm::netsim::cost_model::{self, LinkParams};
use flexcomm::tensor::Layout;
use flexcomm::util::rng::Rng;
use flexcomm::util::table::Table;
use std::time::Instant;

fn heavy_tail(dim: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut g = vec![0.0f32; dim];
    for v in g.iter_mut() {
        let heavy = rng.f64() < 0.05;
        *v = rng.normal_f32(0.0, if heavy { 8.0 } else { 1.0 });
    }
    g
}

fn main() {
    let n = 8;
    let fast = std::env::var("FLEXCOMM_BENCH_FAST").is_ok();
    // In fast mode measure a smaller tensor and extrapolate linearly
    // (Top-k selection is O(G)).
    let sizes: &[(u64, usize, f64)] = if fast {
        &[(100_000_000, 1_000_000, 100.0), (1_000_000_000, 1_000_000, 1000.0)]
    } else {
        &[(100_000_000, 100_000_000, 1.0), (1_000_000_000, 100_000_000, 10.0)]
    };
    let grid = [(10.0, 10.0), (10.0, 5.0), (10.0, 1.0), (100.0, 10.0), (100.0, 5.0), (100.0, 1.0)];

    println!("Table II — AG (compression+comm) vs Ring-AR/HD-AR dense, N=8");
    // Two AG views: compression measured on THIS host (honest), and
    // normalized by the accelerator throughput ratio (paper-comparable —
    // the paper compresses on V100s; see experiments::GPU_COMPRESS_SPEEDUP).
    // HD-AR (halving-doubling) is the dense baseline's latency-optimal
    // variant: same β volume as the ring, log-many α rounds.
    let mut t = Table::new([
        "Tensor", "(α ms, 1/β Gbps)", "AG 0.1 cpu", "AG 0.1 gpu-est",
        "AG 0.001 gpu-est", "Ring-AR", "HD-AR",
    ]);
    for &(label_size, measured, scale) in sizes {
        let g = heavy_tail(measured, 7);
        let layout = Layout::single(measured);
        // Measure compression once per CR (it doesn't depend on the link).
        let mut comp_ms = std::collections::BTreeMap::new();
        for cr in [0.1, 0.001] {
            let mut c = TopK::with_quickselect();
            let t0 = Instant::now();
            let s = c.compress(&g, cr, &layout);
            let dt = t0.elapsed().as_secs_f64() * 1e3 * scale;
            assert_eq!(s.k(), k_for(cr, measured));
            comp_ms.insert(format!("{cr}"), dt);
            println!(
                "measured top-k compress: G={measured} cr={cr} -> {:.1} ms (x{scale} => {:.1} ms)",
                dt / scale,
                dt
            );
        }
        let m_bytes = 4.0 * label_size as f64;
        for (alpha, bw) in grid {
            let l = LinkParams::from_ms_gbps(alpha, bw);
            let comm01 = cost_model::ag_topk(l, m_bytes, n, 0.1) * 1e3;
            let comm001 = cost_model::ag_topk(l, m_bytes, n, 0.001) * 1e3;
            let ring = cost_model::ring_allreduce(l, m_bytes, n) * 1e3;
            let hd = cost_model::halving_doubling_allreduce(l, m_bytes, n) * 1e3;
            t.row([
                format!("1e{}", (label_size as f64).log10() as u32),
                format!("({alpha:.0}, {bw:.0})"),
                format!("{:.0}", comm01 + comp_ms["0.1"]),
                format!("{:.0}", comm01 + comp_ms["0.1"] / GPU_COMPRESS_SPEEDUP),
                format!("{:.0}", comm001 + comp_ms["0.001"] / GPU_COMPRESS_SPEEDUP),
                format!("{ring:.0}"),
                format!("{hd:.0}"),
            ]);
        }
    }
    t.print();
    println!(
        "\nPaper anchors (1e8): (10,10) AG0.1=525 AG0.001=70 Ring=716 | \
         (100,1) AG0.1=4830 AG0.001=380 Ring=7028.\n\
         Shape: AG < Ring everywhere, gap widens at low bandwidth; Ring is \
         NOT (1/c)x slower than AG; HD-AR trims the ring's α-term to log N."
    );

    // Per-topology dense crossover: the same 1e8-param tensor priced on the
    // flat cluster vs two-level layouts sharing the bottleneck link —
    // regenerates the AG-vs-AR decision context per topology (ISSUE 1).
    println!("\nDense crossover per topology — 1e8 params, N=8, inter=(10ms, 1Gbps)");
    let mut tt = Table::new(["Topology", "Ring-AR", "Tree-AR", "HD-AR", "Hier-AR", "chosen"]);
    let presets = experiments::topology_presets(LinkParams::from_ms_gbps(10.0, 1.0));
    for row in experiments::dense_crossover_rows(&presets, 4e8, n) {
        tt.row([
            row.topology,
            format!("{:.0}", row.ring_ms),
            format!("{:.0}", row.tree_ms),
            format!("{:.0}", row.hd_ms),
            row.hier_ms.map(|h| format!("{h:.0}")).unwrap_or_else(|| "-".into()),
            row.chosen.to_string(),
        ]);
    }
    tt.print();
    println!("Shape: the slow link priced nodes-wide flips the dense optimum to Hier-AR.");
}
