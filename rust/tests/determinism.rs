//! Parallel-vs-sequential bitwise determinism — the execution-engine
//! contract (DESIGN.md §7), driven through the public Session API: same
//! seed, `threads = 1` vs `threads = 4` ⇒ identical parameters and
//! identical deterministic metrics (loss, simulated compute/sync seconds,
//! collective kind, CR, selected rank, gain) across DenseSGD, AG-Topk and
//! AR-Topk strategies, including non-power-of-two worker counts. The same
//! harness also guards the observer seam (attaching observers must not
//! perturb a single bit of the numerics) and the control plane (every
//! registered controller replays bitwise across thread counts when its
//! inputs are the simulated, thread-invariant ones — see
//! `every_registered_controller_is_bitwise_identical_across_threads`).
//!
//! Measured compression wall time (`t_comp`) is real elapsed time and
//! therefore legitimately timing-dependent; it is excluded by design —
//! the simulated α-β cost reports (`t_sync`) are what must not move.

use flexcomm::artopk::{ArFlavor, ArTopk, SelectionPolicy};
use flexcomm::compress::{CompressorKind, EfState};
use flexcomm::coordinator::observer::{ProgressPrinter, TrainObserver};
use flexcomm::coordinator::session::{Session, TrainReport};
use flexcomm::coordinator::trainer::{
    CrControl, DenseFlavor, Strategy, TrainConfig,
};
use flexcomm::coordinator::worker::ComputeModel;
use flexcomm::netsim::cost_model::LinkParams;
use flexcomm::netsim::schedule::NetSchedule;
use flexcomm::runtime::HostMlp;
use flexcomm::util::pool::ThreadPool;
use flexcomm::util::rng::Rng;

fn cfg(strategy: Strategy, cr: f64, n_workers: usize, threads: usize) -> TrainConfig {
    TrainConfig {
        n_workers,
        threads,
        steps: 40,
        steps_per_epoch: 20,
        lr: 0.3,
        momentum: 0.6,
        strategy,
        cr: CrControl::Static(cr),
        net: Box::new(NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0))),
        compute: ComputeModel::fixed(0.005),
        eval_every: 0,
        seed: 33,
        ..Default::default()
    }
}

fn run_with(
    strategy: Strategy,
    cr: f64,
    n_workers: usize,
    threads: usize,
    controller: Option<&str>,
    observers: Vec<Box<dyn TrainObserver>>,
) -> TrainReport {
    let mut builder = Session::from_config(cfg(strategy, cr, n_workers, threads));
    if let Some(spec) = controller {
        builder = builder.controller_spec(spec);
    }
    for o in observers {
        builder = builder.observer(o);
    }
    builder
        .source(Box::new(HostMlp::default_preset(33)))
        .build()
        .expect("valid config")
        .run()
}

fn run(strategy: Strategy, cr: f64, n_workers: usize, threads: usize) -> TrainReport {
    run_with(strategy, cr, n_workers, threads, None, Vec::new())
}

fn assert_bitwise_equal(a: &TrainReport, b: &TrainReport, label: &str) {
    assert_eq!(a.params.len(), b.params.len(), "{label}: param dim");
    for (i, (x, y)) in a.params.iter().zip(&b.params).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: param {i}: {x} vs {y}");
    }
    assert_eq!(a.metrics.steps.len(), b.metrics.steps.len(), "{label}: step count");
    for (x, y) in a.metrics.steps.iter().zip(&b.metrics.steps) {
        let s = x.step;
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{label} step {s}: loss");
        assert_eq!(
            x.t_compute.to_bits(),
            y.t_compute.to_bits(),
            "{label} step {s}: t_compute"
        );
        assert_eq!(x.t_sync.to_bits(), y.t_sync.to_bits(), "{label} step {s}: t_sync");
        assert_eq!(x.collective, y.collective, "{label} step {s}: collective");
        assert_eq!(x.cr.to_bits(), y.cr.to_bits(), "{label} step {s}: cr");
        assert_eq!(x.selected_rank, y.selected_rank, "{label} step {s}: rank");
        assert_eq!(x.gain.to_bits(), y.gain.to_bits(), "{label} step {s}: gain");
    }
}

/// The headline property: every strategy family, power-of-two AND
/// non-power-of-two cluster sizes, threads=1 vs threads=4.
#[test]
fn threads_1_and_4_are_bitwise_identical() {
    let cases: [(&str, Strategy, f64); 6] = [
        ("dense-ring", Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0),
        ("dense-hd", Strategy::DenseSgd { flavor: DenseFlavor::HalvingDoubling }, 1.0),
        ("ag-topk", Strategy::AgCompress { kind: CompressorKind::TopK }, 0.05),
        (
            "artopk-star",
            Strategy::ArTopkFixed {
                policy: SelectionPolicy::Star,
                flavor: ArFlavor::Ring,
            },
            0.05,
        ),
        (
            "artopk-var",
            Strategy::ArTopkFixed {
                policy: SelectionPolicy::Var,
                flavor: ArFlavor::Tree,
            },
            0.05,
        ),
        ("flexible", Strategy::Flexible { policy: SelectionPolicy::Star }, 0.05),
    ];
    for (label, strategy, cr) in cases {
        for n_workers in [4usize, 3] {
            let a = run(strategy, cr, n_workers, 1);
            let b = run(strategy, cr, n_workers, 4);
            assert_bitwise_equal(&a, &b, &format!("{label}/n={n_workers}"));
        }
    }
}

/// Oversubscription and odd thread counts change nothing either.
#[test]
fn oversubscribed_threads_are_bitwise_identical() {
    let strategy = Strategy::AgCompress { kind: CompressorKind::TopK };
    let a = run(strategy, 0.02, 5, 1);
    for threads in [3usize, 16] {
        let b = run(strategy, 0.02, 5, threads);
        assert_bitwise_equal(&a, &b, &format!("ag-topk/threads={threads}"));
    }
}

/// The full thread matrix — 1 vs 3 vs 4 vs 16 (undersubscribed, odd,
/// matched, oversubscribed) — across every strategy family AND both
/// selection backends (exact quickselect and sampled-threshold). The
/// persistent pool parks its workers between regions; this pins that the
/// park/wake protocol and the per-worker scratch arenas are bitwise
/// invisible at every pool width, including widths above the host core
/// count where the same OS thread services many logical slots.
#[test]
fn thread_matrix_covers_all_families_and_selection_backends() {
    let cases: [(&str, Strategy, f64); 5] = [
        ("dense-ring", Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0),
        ("ag-topk", Strategy::AgCompress { kind: CompressorKind::TopK }, 0.05),
        ("ag-sampledk", Strategy::AgCompress { kind: CompressorKind::SampledK }, 0.05),
        (
            "artopk-sampled",
            Strategy::ArTopkSampled {
                policy: SelectionPolicy::Star,
                flavor: ArFlavor::Ring,
            },
            0.05,
        ),
        ("flexible", Strategy::Flexible { policy: SelectionPolicy::Star }, 0.05),
    ];
    for (label, strategy, cr) in cases {
        let baseline = run(strategy, cr, 4, 1);
        for threads in [3usize, 4, 16] {
            let b = run(strategy, cr, 4, threads);
            assert_bitwise_equal(&baseline, &b, &format!("{label}/threads={threads}"));
        }
    }
}

/// The kernel-rewired hot paths (ISSUE 10): after the chunked kernel
/// layer took over error-feed (`error_feed_abs_into`), selection
/// magnitudes, residual zeroing (`scatter_zero`), and the lane-split
/// reductions (`sq_norm_lanes` / `sq_norm_gather_lanes` in VAR variance
/// and gain terms), every rewired trajectory must STILL be a pure
/// function of the config — bitwise-identical across the 1/3/4/16 thread
/// matrix. VAR + Tree is deliberate: it drives the gathered variance
/// reduction and the broadcast-index residual path on every lane, the
/// two spots where a thread-dependent reduction order would first show.
#[test]
fn kernel_rewired_paths_bitwise_across_thread_matrix() {
    let cases: [(&str, Strategy, f64); 3] = [
        ("ag-topk", Strategy::AgCompress { kind: CompressorKind::TopK }, 0.05),
        ("ag-sampledk", Strategy::AgCompress { kind: CompressorKind::SampledK }, 0.05),
        (
            "artopk-sampled-var",
            Strategy::ArTopkSampled {
                policy: SelectionPolicy::Var,
                flavor: ArFlavor::Tree,
            },
            0.05,
        ),
    ];
    for (label, strategy, cr) in cases {
        let baseline = run(strategy, cr, 4, 1);
        for threads in [3usize, 4, 16] {
            let b = run(strategy, cr, 4, threads);
            assert_bitwise_equal(&baseline, &b, &format!("kernels/{label}/threads={threads}"));
        }
    }
}

/// The §7 contract extends to the real-workload learners (ISSUE 8): the
/// first-party autograd MLP, resolved from the model registry via
/// `.model_spec("mlp")`, replays bitwise across the full thread matrix
/// (1/3/4/16) under both a dense and a compressed strategy. Its gradient
/// is a batched tape replay per worker — this pins that the tape build,
/// the minibatch draw and the eval pass are pure functions of
/// (seed, worker, step), never of pool scheduling.
#[test]
fn mlp_model_is_bitwise_identical_across_the_thread_matrix() {
    for (label, strategy, cr) in [
        ("dense-ring", Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0),
        ("ag-topk", Strategy::AgCompress { kind: CompressorKind::TopK }, 0.05),
    ] {
        let mk = |threads: usize| {
            Session::from_config(cfg(strategy, cr, 4, threads))
                .model_spec("mlp")
                .build()
                .expect("registry model builds")
                .run()
        };
        let baseline = mk(1);
        assert_eq!(baseline.model, "mlp-spirals[2, 24, 16, 2]", "registry identity");
        for threads in [3usize, 4, 16] {
            let b = mk(threads);
            assert_bitwise_equal(&baseline, &b, &format!("mlp/{label}/threads={threads}"));
        }
    }
}

/// The sampled-threshold backend is not merely self-consistent: an
/// AR-Topk run that selects via the sampled backend is bitwise identical
/// to the exact-selection run with the same policy/flavor/seed. The
/// exact-k repair step makes the two index sets (and hence the whole
/// trajectory) coincide — `t_comp` is the only thing allowed to differ,
/// and it is excluded from the bitwise contract by design.
#[test]
fn sampled_selection_trajectory_matches_exact_selection() {
    for (policy, flavor) in [
        (SelectionPolicy::Star, ArFlavor::Ring),
        (SelectionPolicy::Var, ArFlavor::Tree),
    ] {
        let exact = run(Strategy::ArTopkFixed { policy, flavor }, 0.05, 4, 4);
        let sampled = run(Strategy::ArTopkSampled { policy, flavor }, 0.05, 4, 4);
        assert_bitwise_equal(
            &exact,
            &sampled,
            &format!("sampled-vs-exact/{policy:?}/{flavor:?}"),
        );
    }
}

/// Pool lifecycle: two sequential `Session::run()`s in one process give
/// identical trajectories. Each session spawns its own persistent pool
/// and tears it down on drop, so worker reuse *within* a session (parked
/// threads woken region after region) must be invisible — no state may
/// leak from one region, step, or session into the next.
#[test]
fn sequential_sessions_in_one_process_are_bitwise_identical() {
    for (label, strategy, cr) in [
        ("ag-sampledk", Strategy::AgCompress { kind: CompressorKind::SampledK }, 0.05),
        (
            "artopk-star",
            Strategy::ArTopkFixed {
                policy: SelectionPolicy::Star,
                flavor: ArFlavor::Ring,
            },
            0.05,
        ),
    ] {
        let a = run(strategy, cr, 4, 4);
        let b = run(strategy, cr, 4, 4);
        assert_bitwise_equal(&a, &b, &format!("{label}/second-session"));
    }
}

/// Control-plane determinism (DESIGN.md §10): EVERY registered controller
/// is threads=1-vs-4 bitwise identical when its inputs are the static
/// (simulated, thread-invariant) ones. `comp_scale = 0` zeroes the one
/// measured input (compression wall time) so even the MOO controller's
/// NSGA-II profiles are pure functions of the simulated run — with that,
/// the full trajectory (params, per-step CR decisions, collectives,
/// simulated times) must not move with the thread count. The C2 scenario
/// exercises the triggers: network phase changes and gain drift both fire
/// within 40 steps.
#[test]
fn every_registered_controller_is_bitwise_identical_across_threads() {
    use flexcomm::coordinator::controller::CONTROLLER_TABLE;
    use flexcomm::coordinator::AdaptiveConfig;
    for entry in CONTROLLER_TABLE {
        let mk = |threads: usize| {
            let mut c = cfg(
                Strategy::Flexible { policy: SelectionPolicy::Star },
                0.05,
                4,
                threads,
            );
            c.net = Box::new(NetSchedule::c2(2.0));
            c.comp_scale = 0.0; // kill the measured-time input
            // Short probe windows keep the moo exploration cheap; static
            // and gravac ignore these bounds' probe settings.
            c.cr = CrControl::Adaptive(AdaptiveConfig {
                probe_iters: 3,
                seed: 33,
                ..Default::default()
            });
            Session::from_config(c)
                .controller_spec(entry.name)
                .source(Box::new(HostMlp::default_preset(33)))
                .build()
                .expect("valid config")
                .run()
        };
        let a = mk(1);
        let b = mk(4);
        assert_bitwise_equal(&a, &b, &format!("controller={}", entry.name));
        assert_eq!(a.controller, entry.name, "report names the controller");
    }
}

/// The observer refactor must not perturb numerics: a run with observers
/// attached (a second recorder, a progress printer, a switch listener) is
/// bitwise identical to a bare run — observers read the stream, they
/// never feed back into it. One case runs with a CR-adapting controller
/// attached (gravac: decisions are pure functions of the simulated gain),
/// so the control plane is covered by the same guarantee.
#[test]
fn observers_do_not_perturb_numerics() {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    struct CountEverything {
        steps: Arc<AtomicU64>,
        evals: Arc<AtomicU64>,
    }
    impl TrainObserver for CountEverything {
        fn on_step(&mut self, _m: &flexcomm::coordinator::metrics::StepMetrics) {
            self.steps.fetch_add(1, Ordering::Relaxed);
        }
        fn on_eval(&mut self, _e: &flexcomm::coordinator::observer::EvalRecord) {
            self.evals.fetch_add(1, Ordering::Relaxed);
        }
    }
    for (label, strategy, cr, controller) in [
        ("flexible", Strategy::Flexible { policy: SelectionPolicy::Star }, 0.05, None),
        ("ag-topk", Strategy::AgCompress { kind: CompressorKind::TopK }, 0.05, None),
        (
            "flexible+gravac",
            Strategy::Flexible { policy: SelectionPolicy::Star },
            0.05,
            Some("gravac"),
        ),
    ] {
        let steps = Arc::new(AtomicU64::new(0));
        let evals = Arc::new(AtomicU64::new(0));
        let bare = run_with(strategy, cr, 4, 1, controller, Vec::new());
        let observed = run_with(
            strategy,
            cr,
            4,
            4,
            controller,
            vec![
                Box::new(flexcomm::coordinator::metrics::MetricsLog::default()),
                Box::new(ProgressPrinter::every(1000)),
                Box::new(CountEverything { steps: steps.clone(), evals: evals.clone() }),
            ],
        );
        assert_bitwise_equal(&bare, &observed, &format!("{label}/observers"));
        // The observers really fired — a silently dropped observers Vec
        // would make the bitwise check above pass vacuously.
        assert_eq!(steps.load(Ordering::Relaxed), 40, "{label}: on_step count");
        assert_eq!(evals.load(Ordering::Relaxed), 1, "{label}: final eval only");
    }
}

/// Determinism holds with a `NetworkModel` TRAIT OBJECT driving
/// conditions: a replayed trace wrapped in stochastic modifier layers
/// (jitter + congestion episodes) stays bitwise identical across thread
/// counts under static CR — the modifiers re-derive their perturbation
/// per epoch-bucket, never from shared mutable state (DESIGN.md §9).
#[test]
fn trace_driven_network_models_are_bitwise_identical_across_threads() {
    use flexcomm::netsim::modifiers::{CongestionEpisodes, Jitter};
    use flexcomm::netsim::trace::{TraceModel, TracePoint};
    let net = || {
        let trace = TraceModel::from_points(
            "det",
            vec![
                TracePoint { epoch: 0.0, alpha_ms: 1.0, bw_gbps: 25.0 },
                TracePoint { epoch: 1.0, alpha_ms: 50.0, bw_gbps: 1.0 },
                TracePoint { epoch: 1.5, alpha_ms: 10.0, bw_gbps: 10.0 },
            ],
        )
        .unwrap();
        CongestionEpisodes::wrap(Jitter::wrap(trace, 0.1, 5).unwrap(), 0.3, 6.0, 9).unwrap()
    };
    for (label, strategy, cr) in [
        ("flexible", Strategy::Flexible { policy: SelectionPolicy::Star }, 0.05),
        ("ag-topk", Strategy::AgCompress { kind: CompressorKind::TopK }, 0.05),
    ] {
        let mk = |threads: usize| {
            let mut c = cfg(strategy, cr, 4, threads);
            c.net = Box::new(net());
            Session::from_config(c)
                .source(Box::new(HostMlp::default_preset(33)))
                .build()
                .expect("valid config")
                .run()
        };
        let a = mk(1);
        let b = mk(4);
        assert_bitwise_equal(&a, &b, &format!("{label}/trace-net"));
        assert_eq!(a.network, "trace:det[3 pts]+jitter(0.1)+congestion(0.3,6)");
    }
}

/// Fleet scenarios (per-worker straggler tails, heterogeneous links,
/// elastic membership) preserve the §7 contract across the full thread
/// matrix: `straggler_factor` is a pure function of (worker, step),
/// `worker_link_at`/`active_workers_at` pure functions of (worker, epoch),
/// so t_compute scaling, catch-up charges and membership edges land
/// identically at every pool width — including t_compute, which the
/// bitwise comparison covers.
#[test]
fn fleet_scenarios_are_bitwise_identical_across_the_thread_matrix() {
    use flexcomm::netsim::model::build_scenario;
    for scenario in ["straggler", "hetero", "churn"] {
        let mk = |threads: usize| {
            let mut c = cfg(
                Strategy::Flexible { policy: SelectionPolicy::Star },
                0.05,
                4,
                threads,
            );
            c.net = build_scenario(scenario, 2.0).expect("registry scenario");
            Session::from_config(c)
                .source(Box::new(HostMlp::default_preset(33)))
                .build()
                .expect("valid config")
                .run()
        };
        let baseline = mk(1);
        for threads in [3usize, 4, 16] {
            let b = mk(threads);
            assert_bitwise_equal(&baseline, &b, &format!("{scenario}/threads={threads}"));
        }
    }
}

/// The simulated-cost report of a raw AR-Topk exchange (the paper's Eqn 4
/// object) is identical for any pool, including the traffic accounting.
#[test]
fn artopk_comm_report_identical_across_pools() {
    for n in [3usize, 8] {
        let dim = 4096;
        let mut rng = Rng::new(7);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0; dim];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let link = LinkParams::from_ms_gbps(1.0, 10.0);
        let exchange = |pool: ThreadPool| {
            let mut ef: Vec<EfState> = (0..n).map(|_| EfState::new(dim)).collect();
            let mut art =
                ArTopk::new(SelectionPolicy::Var, ArFlavor::Ring).with_pool(pool);
            art.exchange(&grads, &mut ef, 0.03, 2, link)
        };
        let a = exchange(ThreadPool::serial());
        let b = exchange(ThreadPool::new(4));
        assert_eq!(a.comm, b.comm, "n={n}: CommReport must not depend on threads");
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.update.indices, b.update.indices);
        assert_eq!(a.update.values, b.update.values);
    }
}
