//! Communication collectives over in-process worker buffers.
//!
//! Every op REALLY moves/reduces the data (numerics are exact, not mocked)
//! and returns the wall-time a cluster of N single-GPU nodes on the
//! simulated link would have spent, derived from the op's round structure:
//! each round costs `α + bytes_sent_per_worker · β`. For power-of-two N the
//! totals equal the closed forms in [`crate::netsim::cost_model`] — that
//! equivalence is what the unit tests pin down (the paper validates the
//! same algebra on hardware in Tables II/VI).

pub mod allgather;
pub mod broadcast;
pub mod ps;
pub mod ring_allreduce;
pub mod tree_allreduce;

pub use allgather::{allgather_concat, allgather_sparse};
pub use broadcast::broadcast;
pub use ps::ps_exchange;
pub use ring_allreduce::ring_allreduce;
pub use tree_allreduce::tree_allreduce;

use crate::netsim::cost_model::LinkParams;

/// Simulated time + traffic accounting for one collective call.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommReport {
    /// Simulated wall-clock seconds for the whole op.
    pub seconds: f64,
    /// Total bytes a single worker put on the wire (per-worker egress).
    pub bytes_per_worker: f64,
    /// Number of latency-bearing rounds.
    pub rounds: u32,
}

impl CommReport {
    pub(crate) fn add_round(&mut self, link: LinkParams, bytes: f64) {
        self.seconds += link.alpha + bytes * link.beta;
        self.bytes_per_worker += bytes;
        self.rounds += 1;
    }

    pub fn merge(&mut self, other: CommReport) {
        self.seconds += other.seconds;
        self.bytes_per_worker += other.bytes_per_worker;
        self.rounds += other.rounds;
    }
}

/// Which collective a training step used (for the Fig 8 density plots and
/// the metrics log).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    RingAllreduce,
    TreeAllreduce,
    AllgatherTopk,
    ArTopkRing,
    ArTopkTree,
    PsStar,
}

impl CollectiveKind {
    pub fn name(&self) -> &'static str {
        match self {
            CollectiveKind::RingAllreduce => "Ring-AR",
            CollectiveKind::TreeAllreduce => "Tree-AR",
            CollectiveKind::AllgatherTopk => "AG",
            CollectiveKind::ArTopkRing => "ART-Ring",
            CollectiveKind::ArTopkTree => "ART-Tree",
            CollectiveKind::PsStar => "PS",
        }
    }
}

pub(crate) fn ceil_log2(n: usize) -> u32 {
    assert!(n >= 1);
    usize::BITS - (n - 1).leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
    }

    #[test]
    fn report_accumulates() {
        let l = LinkParams::from_ms_gbps(1.0, 8.0); // beta = 1e-9 s/B
        let mut r = CommReport::default();
        r.add_round(l, 1e6);
        assert!((r.seconds - (1e-3 + 1e-3)).abs() < 1e-12);
        assert_eq!(r.rounds, 1);
        let mut r2 = CommReport::default();
        r2.add_round(l, 2e6);
        r.merge(r2);
        assert_eq!(r.rounds, 2);
        assert!((r.bytes_per_worker - 3e6).abs() < 1e-6);
    }
}
