//! Automatic STAR/VAR worker-selection switching — the paper's stated
//! future work (§5): "combine the two approaches where AR-Topk
//! automatically switches between the two based on the DNN test
//! performance with each approach."
//!
//! Trial/commit controller: run a trial window under STAR, then one under
//! VAR, score each by the mean per-step loss improvement, commit to the
//! winner for a longer period, then re-trial. All thresholds are
//! data-driven (loss deltas), no oracle access.

use crate::artopk::SelectionPolicy;
use crate::coordinator::controller::ControllerError;

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    TrialStar,
    TrialVar,
    Committed(SelectionPolicy),
}

/// Trial/commit policy switcher.
#[derive(Debug, Clone)]
pub struct PolicySwitcher {
    phase: Phase,
    /// Steps per trial window.
    pub trial_window: u64,
    /// Steps to stay committed before re-trialling.
    pub commit_period: u64,
    steps_in_phase: u64,
    first_loss: Option<f64>,
    last_loss: f64,
    star_score: f64,
    var_score: f64,
    /// Number of completed trial->commit cycles (observability).
    pub cycles: u64,
}

impl PolicySwitcher {
    /// Validate trial/commit windows: a trial needs >= 2 observations to
    /// bracket at least one loss delta, and the commit period must cover
    /// the trial it follows. Surfaced as a typed error (was an `assert!`
    /// that panicked at construction — the builder now rejects bad
    /// windows as
    /// [`ConfigError::Controller`](crate::coordinator::session::ConfigError)).
    pub fn validate(trial_window: u64, commit_period: u64) -> Result<(), ControllerError> {
        if trial_window >= 2 && commit_period >= trial_window {
            Ok(())
        } else {
            Err(ControllerError::BadPolicyWindows { trial_window, commit_period })
        }
    }

    pub fn new(trial_window: u64, commit_period: u64) -> Result<Self, ControllerError> {
        Self::validate(trial_window, commit_period)?;
        Ok(PolicySwitcher {
            phase: Phase::TrialStar,
            trial_window,
            commit_period,
            steps_in_phase: 0,
            first_loss: None,
            last_loss: f64::NAN,
            star_score: 0.0,
            var_score: 0.0,
            cycles: 0,
        })
    }

    /// The policy to use for the upcoming step.
    pub fn current(&self) -> SelectionPolicy {
        match self.phase {
            Phase::TrialStar => SelectionPolicy::Star,
            Phase::TrialVar => SelectionPolicy::Var,
            Phase::Committed(p) => p,
        }
    }

    /// Committed policy if out of trial (for logs/tests).
    pub fn committed(&self) -> Option<SelectionPolicy> {
        match self.phase {
            Phase::Committed(p) => Some(p),
            _ => None,
        }
    }

    /// Record the loss observed on a completed step; advances phases.
    pub fn observe(&mut self, loss: f64) {
        if self.first_loss.is_none() {
            self.first_loss = Some(loss);
        }
        self.last_loss = loss;
        self.steps_in_phase += 1;
        match self.phase {
            Phase::TrialStar if self.steps_in_phase >= self.trial_window => {
                self.star_score = self.window_improvement();
                self.enter(Phase::TrialVar);
            }
            Phase::TrialVar if self.steps_in_phase >= self.trial_window => {
                self.var_score = self.window_improvement();
                // Higher improvement (loss drop per step) wins; ties -> STAR
                // (cheaper: no variance allgather).
                let winner = if self.var_score > self.star_score {
                    SelectionPolicy::Var
                } else {
                    SelectionPolicy::Star
                };
                self.cycles += 1;
                self.enter(Phase::Committed(winner));
            }
            Phase::Committed(_) if self.steps_in_phase >= self.commit_period => {
                self.enter(Phase::TrialStar);
            }
            _ => {}
        }
    }

    fn window_improvement(&self) -> f64 {
        let first = self.first_loss.unwrap_or(self.last_loss);
        // `first_loss` is recorded AFTER the window's first step, so W
        // observations bracket only W-1 per-step deltas: divide by the
        // delta count, not the observation count (which biased every
        // trial score low by (W-1)/W).
        let deltas = self.steps_in_phase.saturating_sub(1).max(1);
        (first - self.last_loss) / deltas as f64
    }

    fn enter(&mut self, phase: Phase) {
        self.phase = phase;
        self.steps_in_phase = 0;
        self.first_loss = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trial_then_commit_cycle() {
        let mut s = PolicySwitcher::new(5, 20).unwrap();
        assert_eq!(s.current(), SelectionPolicy::Star);
        // STAR trial: loss falls fast (improvement 0.1/step).
        for i in 0..5 {
            s.observe(1.0 - 0.1 * i as f64);
        }
        assert_eq!(s.current(), SelectionPolicy::Var);
        // VAR trial: loss falls slowly.
        for i in 0..5 {
            s.observe(0.6 - 0.01 * i as f64);
        }
        assert_eq!(s.committed(), Some(SelectionPolicy::Star));
        assert_eq!(s.cycles, 1);
        // Committed for 20 steps, then re-trials.
        for _ in 0..20 {
            s.observe(0.5);
        }
        assert_eq!(s.current(), SelectionPolicy::Star);
        assert!(s.committed().is_none());
    }

    #[test]
    fn var_wins_when_it_improves_more() {
        let mut s = PolicySwitcher::new(4, 8).unwrap();
        for _ in 0..4 {
            s.observe(1.0); // STAR: flat
        }
        for i in 0..4 {
            s.observe(1.0 - 0.2 * i as f64); // VAR: improving
        }
        assert_eq!(s.committed(), Some(SelectionPolicy::Var));
    }

    #[test]
    fn ties_prefer_star() {
        let mut s = PolicySwitcher::new(3, 6).unwrap();
        for _ in 0..3 {
            s.observe(1.0);
        }
        for _ in 0..3 {
            s.observe(1.0);
        }
        assert_eq!(s.committed(), Some(SelectionPolicy::Star));
    }

    /// A W-observation trial brackets W-1 per-step deltas; the score must
    /// be delta-sum / (W-1), not / W (the old off-by-one biased every
    /// trial low). Known data: 1.0, 0.9, 0.8, 0.7 ⇒ exactly 0.1/step.
    #[test]
    fn window_improvement_divides_by_delta_count() {
        let mut s = PolicySwitcher::new(4, 8).unwrap();
        for i in 0..4 {
            s.observe(1.0 - 0.1 * i as f64);
        }
        assert!(
            (s.star_score - 0.1).abs() < 1e-12,
            "STAR trial score {} != 0.1/step",
            s.star_score
        );
        // VAR trial with 0.02/step decline scores exactly 0.02.
        for i in 0..4 {
            s.observe(0.7 - 0.02 * i as f64);
        }
        assert!(
            (s.var_score - 0.02).abs() < 1e-12,
            "VAR trial score {} != 0.02/step",
            s.var_score
        );
        assert_eq!(s.committed(), Some(SelectionPolicy::Star));
    }

    /// Window validation is a typed error, not a construction panic (the
    /// PR 3 no-panic contract): boundary (2, 2) is the smallest valid
    /// configuration, and each violated bound names itself.
    #[test]
    fn bad_windows_are_typed_errors() {
        assert!(PolicySwitcher::new(2, 2).is_ok());
        assert_eq!(
            PolicySwitcher::new(1, 0).err(),
            Some(ControllerError::BadPolicyWindows { trial_window: 1, commit_period: 0 })
        );
        assert_eq!(
            PolicySwitcher::new(10, 9).err(),
            Some(ControllerError::BadPolicyWindows { trial_window: 10, commit_period: 9 })
        );
        assert!(PolicySwitcher::validate(2, 1_000_000).is_ok());
    }
}
