//! Sampled-threshold Top-k with **exact-k repair** — cheaper selection,
//! bitwise-identical output (DGC-style hierarchical selection, PAPERS.md).
//!
//! Full quickselect builds and partitions an O(G) pair buffer every step.
//! This backend instead (1) draws a small deterministic sample of
//! magnitudes, (2) picks a conservative threshold from the sample's order
//! statistics, (3) makes ONE filtering pass over the gradient keeping only
//! entries that rank at-or-before the threshold, and (4) runs the exact
//! selection on those ~O(k) survivors.
//!
//! ## The exact-k repair contract
//!
//! The output index set and values are **bitwise identical** to
//! [`crate::compress::topk::topk_indices_select`] (and the paper's heap)
//! for every input, including ties, NaN and ±inf — not approximately, not
//! w.h.p. The argument rests on `mag_desc_idx_asc` being a *total*
//! order (descending |v|, NaN smallest, ties by ascending index):
//!
//! 1. The threshold `t` is a real element of `g`, so "ranks at-or-before
//!    `t`" selects an exact **prefix** of the totally-ordered gradient.
//! 2. If that prefix has `>= k` elements it necessarily contains the
//!    top-k prefix,
//!    and `select_nth_unstable_by(k-1)` over the survivors returns exactly
//!    the same k pairs as running it over all of `g` (repair step).
//! 3. If the sample misjudged and the prefix has `< k` elements, we fall
//!    back to the full quickselect — so correctness never depends on the
//!    sample being representative; only speed does.
//!
//! The sample itself is a pure function of `(g.len(), k)` via
//! [`crate::util::rng::Rng`] — no per-worker or per-step state — so the
//! selection is deterministic and identical across workers, steps, thread
//! counts and sessions. Property tests below pin equivalence on random
//! dims/CRs including k=0, k=dim, heavy ties, and NaN/±inf poisoning.

use crate::compress::topk::{mag_desc_idx_asc, topk_indices_select, SelectScratch};
use crate::compress::{k_for, Compressor, SparseGrad};
use crate::tensor::{kernels, Layout};
use crate::util::rng::Rng;

/// Draw the deterministic sample and pick the conservative threshold pair
/// for a gradient of `len` entries at rank `k`; `mag_at(i)` supplies
/// `|g[i]|` (the g-path computes it, the mags-path reads it). Callers
/// guarantee `0 < k < len`.
///
/// The sample is seeded purely from the problem shape. With replacement:
/// duplicates only blur the threshold estimate, never correctness (see
/// the repair contract above), and avoid the O(s^2) cost of distinct
/// sampling at this size.
fn sample_threshold(
    len: usize,
    k: usize,
    sample: &mut Vec<(f32, u32)>,
    mut mag_at: impl FnMut(usize) -> f32,
) -> (f32, u32) {
    let s = len.min(64 + len / 8);
    let mut rng = Rng::new(
        0x5A4D_714B_u64
            ^ (len as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (k as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9),
    );
    sample.clear();
    sample.extend((0..s).map(|_| {
        let i = rng.below(len);
        (mag_at(i), i as u32)
    }));

    // Conservative sample rank: scale k to the sample plus slack, so the
    // induced prefix usually holds >= k survivors without ballooning.
    let q = (2 * ((k * s + len - 1) / len) + 8).min(s);
    sample.select_nth_unstable_by(q - 1, mag_desc_idx_asc);
    sample[q - 1]
}

/// Exact-k repair over the filtered prefix: `false` means the sample
/// misjudged (prefix held `< k` survivors — possible, not wrong) and the
/// caller must run its exact fallback.
fn repair_prefix(cand: &mut Vec<(f32, u32)>, k: usize, out: &mut Vec<u32>) -> bool {
    if cand.len() < k {
        return false;
    }
    if cand.len() > k {
        cand.select_nth_unstable_by(k - 1, mag_desc_idx_asc);
    }
    out.extend(cand[..k].iter().map(|&(_, i)| i));
    out.sort_unstable();
    true
}

/// Sampled-threshold top-`k` of `g` into `out` (ascending indices),
/// bitwise-identical to exact selection. `scratch` is only an arena.
pub fn sampled_topk_into(g: &[f32], k: usize, scratch: &mut SelectScratch, out: &mut Vec<u32>) {
    let len = g.len();
    let k = k.min(len);
    out.clear();
    if k == 0 {
        return;
    }
    if k == len {
        out.extend(0..len as u32);
        return;
    }

    let threshold = sample_threshold(len, k, &mut scratch.sample, |i| g[i].abs());

    // One branch-free filtering pass: keep the exact prefix "ranks
    // at-or-before t" (kernels::threshold_filter_into — bitwise-equal to
    // the comparator push-loop it replaced).
    kernels::threshold_filter_into(g, threshold, &mut scratch.pairs);

    if !repair_prefix(&mut scratch.pairs, k, out) {
        out.extend(topk_indices_select(g, k));
    }
}

/// [`sampled_topk_into`] over a PRECOMPUTED magnitude buffer (`mags[i]`
/// must equal `|g[i]|`): identical selection — the sample, threshold,
/// filter and repair all see the same (magnitude, index) pairs.
pub fn sampled_topk_mags_into(
    mags: &[f32],
    k: usize,
    scratch: &mut SelectScratch,
    out: &mut Vec<u32>,
) {
    let len = mags.len();
    let k = k.min(len);
    out.clear();
    if k == 0 {
        return;
    }
    if k == len {
        out.extend(0..len as u32);
        return;
    }

    let threshold = sample_threshold(len, k, &mut scratch.sample, |i| mags[i]);
    kernels::threshold_filter_mags_into(mags, threshold, &mut scratch.pairs);

    if !repair_prefix(&mut scratch.pairs, k, out) {
        // `abs` is idempotent on magnitudes (non-negative or NaN), so the
        // g-path fallback selects identically over `mags`.
        out.extend(topk_indices_select(mags, k));
    }
}

/// Fused-tensor Top-k compressor over the sampled-threshold backend.
/// Output is bitwise-identical to [`crate::compress::TopK`]; only
/// `t_comp` differs. Carries its own scratch arena (per worker lane).
#[derive(Debug, Clone, Default)]
pub struct SampledK {
    scratch: SelectScratch,
}

impl SampledK {
    pub fn new() -> Self {
        SampledK::default()
    }
}

impl Compressor for SampledK {
    fn name(&self) -> &'static str {
        "sampledk"
    }

    fn compress(&mut self, g: &[f32], cr: f64, layout: &Layout) -> SparseGrad {
        let mut out = SparseGrad::default();
        self.compress_into(g, cr, layout, &mut out);
        out
    }

    fn compress_into(&mut self, g: &[f32], cr: f64, _layout: &Layout, out: &mut SparseGrad) {
        let k = k_for(cr, g.len());
        let mut indices = std::mem::take(&mut out.indices);
        sampled_topk_into(g, k, &mut self.scratch, &mut indices);
        out.values.clear();
        out.values.extend(indices.iter().map(|&i| g[i as usize]));
        out.indices = indices;
        out.dense_len = g.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::topk::{select_into, topk_indices, SelectBackend};
    use crate::compress::{EfState, RandomK, TopK};
    use crate::util::proptest::{check, ensure};

    fn sampled(g: &[f32], k: usize) -> Vec<u32> {
        let mut scratch = SelectScratch::default();
        let mut out = Vec::new();
        sampled_topk_into(g, k, &mut scratch, &mut out);
        out
    }

    #[test]
    fn k_edges_match_exact() {
        let g = [0.3f32, -2.0, 0.0, 5.0, 1.0];
        assert_eq!(sampled(&g, 0), Vec::<u32>::new());
        assert_eq!(sampled(&g, 5), vec![0, 1, 2, 3, 4]);
        assert_eq!(sampled(&g, 99), vec![0, 1, 2, 3, 4]);
        assert_eq!(sampled(&g, 2), topk_indices(&g, 2));
        assert_eq!(sampled(&[], 0), Vec::<u32>::new());
    }

    /// The headline contract: identical index set to both exact backends
    /// on random dims/k, including large-ish gradients where the sampled
    /// threshold actually engages (len >> sample slack).
    #[test]
    fn sampled_equals_exact_randomized() {
        check("sampled == exact selection", 120, |g| {
            let n = g.usize_in(1, 3000);
            let v = g.vec_normal(n, 1.0);
            let k = g.usize_in(0, n);
            let got = sampled(&v, k);
            ensure(got == topk_indices_select(&v, k), format!("vs quickselect n={n} k={k}"))?;
            ensure(got == topk_indices(&v, k), format!("vs heap n={n} k={k}"))
        });
    }

    /// Heavy ties: quantized magnitudes make the threshold pair land in
    /// the middle of long equal-magnitude runs, where only the index
    /// tiebreak keeps the prefix exact.
    #[test]
    fn sampled_equals_exact_under_ties() {
        check("sampled == exact under ties", 100, |g| {
            let n = g.usize_in(1, 1200);
            let levels = g.usize_in(1, 4) as f32;
            let v: Vec<f32> = (0..n)
                .map(|_| {
                    let q = (g.f32_in(-levels, levels)).round();
                    if g.bool() {
                        q
                    } else {
                        -q
                    }
                })
                .collect();
            let k = g.usize_in(0, n);
            ensure(
                sampled(&v, k) == topk_indices_select(&v, k),
                format!("ties mismatch n={n} k={k}"),
            )
        });
    }

    /// NaN/±inf poisoning (via the crate `nan_min_cmp` total order): the
    /// sampled threshold may itself be NaN or inf; equivalence must hold.
    #[test]
    fn sampled_equals_exact_with_nan_inf() {
        check("sampled == exact with NaN/inf", 100, |g| {
            let n = g.usize_in(1, 800);
            let mut v = g.vec_normal(n, 1.0);
            for _ in 0..g.usize_in(0, n / 3 + 1) {
                let at = g.usize_in(0, n - 1);
                v[at] = *g.choose(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0]);
            }
            let k = g.usize_in(0, n);
            ensure(
                sampled(&v, k) == topk_indices_select(&v, k),
                format!("NaN/inf mismatch n={n} k={k}"),
            )
        });
    }

    /// All-identical magnitudes force the worst tie case: the prefix is
    /// resolved purely by index.
    #[test]
    fn constant_gradient_resolved_by_index() {
        let g = vec![1.0f32; 500];
        assert_eq!(sampled(&g, 7), (0..7).collect::<Vec<u32>>());
        let g = vec![f32::INFINITY; 300];
        assert_eq!(sampled(&g, 3), vec![0, 1, 2]);
    }

    /// `select_into` dispatch: every backend, same answer.
    #[test]
    fn all_backends_agree_via_select_into() {
        check("select_into backends agree", 60, |g| {
            let n = g.usize_in(1, 600);
            let v = g.vec_normal(n, 1.0);
            let k = g.usize_in(0, n);
            let mut scratch = SelectScratch::default();
            let mut heap = Vec::new();
            let mut quick = Vec::new();
            let mut samp = Vec::new();
            select_into(SelectBackend::Heap, &v, k, &mut scratch, &mut heap);
            select_into(SelectBackend::Quickselect, &v, k, &mut scratch, &mut quick);
            select_into(SelectBackend::Sampled, &v, k, &mut scratch, &mut samp);
            ensure(heap == quick && quick == samp, format!("backend split n={n} k={k}"))
        });
    }

    fn bitwise_eq(a: &SparseGrad, b: &SparseGrad) -> bool {
        a.dense_len == b.dense_len
            && a.indices == b.indices
            && a.values.len() == b.values.len()
            && a.values.iter().zip(&b.values).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    /// Arena reuse across consecutive steps must be invisible: one
    /// compressor instance driving `compress_into` into ONE reused
    /// `SparseGrad` arena over >= 3 steps is bitwise-equal to a fresh
    /// `compress` per step. Covers every backend with its own scratch
    /// semantics (TopK heap/quickselect, SampledK, RandomK's stepped RNG).
    #[test]
    fn arena_reuse_is_bitwise_invisible() {
        check("compress_into arena == fresh compress", 40, |g| {
            let n = g.usize_in(1, 400);
            let layout = Layout::single(n);
            let steps = g.usize_in(3, 5);
            let grads: Vec<Vec<f32>> = (0..steps).map(|_| g.vec_normal(n, 1.0)).collect();
            let cr = g.f64_in(0.01, 1.0);
            run_pair(TopK::new(), TopK::new(), &grads, cr, &layout, "topk-heap")?;
            run_pair(
                TopK::with_quickselect(),
                TopK::with_quickselect(),
                &grads,
                cr,
                &layout,
                "topk-quick",
            )?;
            run_pair(SampledK::new(), SampledK::new(), &grads, cr, &layout, "sampledk")?;
            run_pair(RandomK::new(7), RandomK::new(7), &grads, cr, &layout, "randomk")
        });
    }

    fn run_pair<C: Compressor>(
        mut fresh: C,
        mut arena_c: C,
        grads: &[Vec<f32>],
        cr: f64,
        layout: &Layout,
        label: &str,
    ) -> crate::util::proptest::PropResult {
        let mut arena = SparseGrad::default();
        for (step, grad) in grads.iter().enumerate() {
            let want = fresh.compress(grad, cr, layout);
            arena_c.compress_into(grad, cr, layout, &mut arena);
            ensure(bitwise_eq(&want, &arena), format!("{label} diverged at step {step}"))?;
        }
        Ok(())
    }

    /// The swap-based error-feedback cycle (error_fed_into + update_swap)
    /// must match the allocating one across steps — residuals, staged
    /// buffers and compressed output all bitwise.
    #[test]
    fn ef_swap_cycle_matches_allocating_cycle() {
        check("EfState swap == allocating", 40, |g| {
            let n = g.usize_in(1, 300);
            let layout = Layout::single(n);
            let cr = g.f64_in(0.01, 0.9);
            let steps = g.usize_in(3, 6);
            let grads: Vec<Vec<f32>> = (0..steps).map(|_| g.vec_normal(n, 1.0)).collect();
            let mut ef_a = EfState::new(n);
            let mut ef_b = EfState::new(n);
            let mut comp_a = SampledK::new();
            let mut comp_b = SampledK::new();
            let mut staged = Vec::new();
            let mut part = SparseGrad::default();
            for (step, grad) in grads.iter().enumerate() {
                // Allocating path.
                let g_e = ef_a.error_fed(grad);
                let sparse = comp_a.compress(&g_e, cr, &layout);
                ef_a.update(g_e, &sparse);
                // Arena path.
                ef_b.error_fed_into(grad, &mut staged);
                comp_b.compress_into(&staged, cr, &layout, &mut part);
                ef_b.update_swap(&mut staged, &part);
                ensure(bitwise_eq(&sparse, &part), format!("sparse diverged at {step}"))?;
                ensure(
                    ef_a.residual.iter().zip(&ef_b.residual).all(|(x, y)| x.to_bits() == y.to_bits()),
                    format!("residual diverged at {step}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn compressor_interface() {
        let mut c = SampledK::new();
        let layout = Layout::single(10);
        let g: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let s = c.compress(&g, 0.3, &layout);
        assert_eq!(s.k(), 3);
        assert_eq!(s.indices, vec![7, 8, 9]);
        assert_eq!(s.values, vec![7.0, 8.0, 9.0]);
        assert_eq!(c.name(), "sampledk");
    }
}
