//! The control plane: pluggable adaptation controllers (DESIGN.md §10).
//!
//! The paper's headline contribution is a controller that retunes the
//! compression ratio and switches collectives as the network drifts — and
//! GraVAC (Tyagi & Swany, 2023) and Agarwal et al. (2021) both show that
//! *which* adaptation policy wins is workload- and network-dependent. That
//! makes the control plane a seam, exactly like strategies (`CommStrategy`,
//! DESIGN.md §8) and environments (`NetworkModel`, §9): a [`Controller`] is
//! a plug-in object the engine consults after every recorded step, not
//! logic spliced into the trainer.
//!
//! The protocol is decision-based: [`Controller::observe`] sees a
//! [`ControlCtx`] (the recorded step's metrics plus the probed network
//! view) and returns typed [`ControlDecision`]s — set the CR, switch the
//! collective, switch the AR-Topk selection policy, or request a
//! checkpointed candidate exploration. Exploration itself is engine-owned:
//! the [`ExplorationHarness`] runs the checkpoint → probe-candidates →
//! restore loop (with overhead accounting and the delivery semantics for
//! decisions born on rolled-back steps) in ONE place, and feeds the
//! measured [`CandidateProfile`](crate::moo::problem::CandidateProfile)s
//! back through [`Controller::on_exploration`].
//!
//! Built-ins, registered in [`CONTROLLER_TABLE`] (the one name table that
//! feeds `--controller` parsing and usage text, mirroring `STRATEGY_TABLE`
//! and `NET_TABLE`):
//! * `static` — [`StaticController`]: no decisions, the CR stays wherever
//!   the config put it.
//! * `moo` — [`MooController`]: the paper's §3-E NSGA-II knee-point
//!   controller (checkpointed CR-ladder exploration on gain drift,
//!   cost-model re-solve on network change), behavior-pinned bitwise
//!   against the pre-refactor implementation.
//! * `gravac` — [`GravacController`]: a GraVAC-style threshold ladder that
//!   walks the CR ladder on observed compression gain alone — no MOO
//!   re-solves, no exploration, and therefore bitwise thread-invariant.
//!
//! The STAR/VAR trial/commit logic ([`PolicySwitchController`]) is a
//! controller too — composed alongside the CR controller (via
//! [`CompositeController`]) when the `artopk-auto` strategy is configured,
//! instead of living inside the strategy object.

pub mod gravac;
pub mod harness;
pub mod moo;

pub use gravac::{GravacConfig, GravacController};
pub use harness::{ExplorationHarness, ExplorationOutcome, ExplorationRequest};
pub use moo::{AdaptiveConfig, MooController};

use crate::artopk::SelectionPolicy;
use crate::collectives::CollectiveKind;
use crate::coordinator::metrics::StepMetrics;
use crate::coordinator::policy_switch::PolicySwitcher;
use crate::coordinator::trainer::{CrControl, Strategy, TrainConfig};
use crate::netsim::cost_model::LinkParams;
use std::fmt;

/// What a controller sees after every RECORDED step. Exploration steps are
/// internal to the harness — controllers observe the committed timeline
/// only, so their state never reflects a rolled-back step.
#[derive(Debug, Clone, Copy)]
pub struct ControlCtx<'a> {
    /// The step that just ran and was recorded.
    pub metrics: &'a StepMetrics,
    /// The probe detected an α/bandwidth drift at this step (§3-C).
    pub net_changed: bool,
    /// The probed (noisy) inter link this step planned against.
    pub probed: LinkParams,
    /// CR currently in effect.
    pub cur_cr: f64,
    /// Effective message bytes (`4 · dim · msg_scale`).
    pub model_bytes: f64,
    pub n_workers: usize,
    /// Whether the active strategy compresses (CR semantics apply).
    pub compressed: bool,
    /// Worst per-worker straggler slowdown this step
    /// ([`NetworkModel::straggler_factor`](crate::netsim::model::NetworkModel::straggler_factor)
    /// maxed over the fleet): 1.0 on straggler-free environments.
    pub straggler_factor: f64,
    /// Workers active this step under elastic membership
    /// ([`NetworkModel::active_workers_at`](crate::netsim::model::NetworkModel::active_workers_at)):
    /// equals `n_workers` on churn-free environments.
    pub active_workers: usize,
}

/// One typed control action (see [`ControlDecision`]).
#[derive(Debug, Clone, PartialEq)]
pub enum ControlAction {
    /// Set the compression ratio for subsequent steps.
    SetCr(f64),
    /// Pin the strategy's collective (delivered via
    /// [`CommStrategy::set_collective`](crate::coordinator::strategy::CommStrategy::set_collective);
    /// strategies that re-plan per step may decline). The observable
    /// collective change surfaces through the regular per-step switch
    /// detection, so no separate event is fired for this action.
    SwitchCollective(CollectiveKind),
    /// Switch the AR-Topk worker-selection policy (delivered via
    /// [`CommStrategy::set_selection_policy`](crate::coordinator::strategy::CommStrategy::set_selection_policy)).
    SwitchSelectionPolicy(SelectionPolicy),
    /// Ask the engine to run a checkpointed candidate exploration; the
    /// measured profiles come back through [`Controller::on_exploration`].
    RequestExploration(ExplorationRequest),
}

/// A decision record: who decided ([`Controller::name`]), why (a short
/// static trigger tag like `"gain-drift"` or `"net-change"`), and what.
/// `by`/`reason` are carried into the observer events
/// ([`CrChange`](crate::coordinator::observer::CrChange),
/// [`StrategySwitch`](crate::coordinator::observer::StrategySwitch)) so
/// logs can attribute every adaptation.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlDecision {
    pub by: &'static str,
    pub reason: &'static str,
    pub action: ControlAction,
}

/// A pluggable adaptation controller.
///
/// Lifecycle per recorded step: the engine calls `observe` once, then
/// applies the returned decisions in order. A
/// [`ControlAction::RequestExploration`] decision makes the engine run the
/// [`ExplorationHarness`] (checkpoint → probe each candidate CR → restore)
/// and hand the measured profiles to `on_exploration`, whose decisions are
/// applied the same way (one level of follow-up exploration is allowed;
/// deeper recursion is dropped as a runaway guard).
///
/// Determinism: a controller whose decisions are pure functions of the
/// observed (simulated) metrics — like [`GravacController`] — preserves
/// the §7 bitwise thread-invariance. [`MooController`] reads MEASURED
/// compression time and is therefore only reproducible when that input is
/// deterministic (e.g. `comp_scale = 0`, see `rust/tests/determinism.rs`).
pub trait Controller: Send {
    /// Registry/display name (decision attribution, reports).
    fn name(&self) -> &'static str;

    /// One recorded step completed; return any control decisions.
    fn observe(&mut self, ctx: &ControlCtx<'_>) -> Vec<ControlDecision>;

    /// Measured candidate profiles from an exploration this controller
    /// requested. Default: ignore (for controllers that never explore).
    fn on_exploration(&mut self, _res: &ExplorationOutcome) -> Vec<ControlDecision> {
        Vec::new()
    }

    /// Whether this controller adapts the CR (requires a compressed
    /// strategy; the builder rejects the combination otherwise).
    fn adapts_cr(&self) -> bool {
        false
    }

    /// CR to start the run at (`None` = whatever [`CrControl`] says).
    fn initial_cr(&self) -> Option<f64> {
        None
    }
}

/// The no-op controller: the CR stays wherever [`CrControl`] put it and
/// the strategy adapts nothing — the baseline every adaptive controller
/// is compared against.
#[derive(Debug, Default, Clone, Copy)]
pub struct StaticController;

impl Controller for StaticController {
    fn name(&self) -> &'static str {
        "static"
    }

    fn observe(&mut self, _ctx: &ControlCtx<'_>) -> Vec<ControlDecision> {
        Vec::new()
    }
}

/// Runs several controllers side by side (e.g. a CR controller composed
/// with the STAR/VAR [`PolicySwitchController`] for `artopk-auto`).
/// `observe` concatenates each sub-controller's decisions in registration
/// order; exploration results are routed back to the sub-controller whose
/// [`Controller::name`] matches the requesting decision's `by` tag (names
/// within one composite must therefore be unique).
pub struct CompositeController {
    subs: Vec<Box<dyn Controller>>,
}

impl CompositeController {
    pub fn new(subs: Vec<Box<dyn Controller>>) -> Self {
        CompositeController { subs }
    }

    pub fn pair(a: Box<dyn Controller>, b: Box<dyn Controller>) -> Self {
        CompositeController { subs: vec![a, b] }
    }
}

impl Controller for CompositeController {
    fn name(&self) -> &'static str {
        "composite"
    }

    fn observe(&mut self, ctx: &ControlCtx<'_>) -> Vec<ControlDecision> {
        self.subs.iter_mut().flat_map(|s| s.observe(ctx)).collect()
    }

    fn on_exploration(&mut self, res: &ExplorationOutcome) -> Vec<ControlDecision> {
        match self.subs.iter_mut().find(|s| s.name() == res.by) {
            Some(s) => s.on_exploration(res),
            None => Vec::new(),
        }
    }

    fn adapts_cr(&self) -> bool {
        self.subs.iter().any(|s| s.adapts_cr())
    }

    fn initial_cr(&self) -> Option<f64> {
        self.subs.iter().find_map(|s| s.initial_cr())
    }
}

/// STAR/VAR trial/commit selection-policy switching as a controller (the
/// paper's §5 future work, formerly embedded in the `artopk-auto`
/// strategy): run a trial window under each policy, score by per-step loss
/// improvement, commit to the winner for a longer period, re-trial.
/// Emits [`ControlAction::SwitchSelectionPolicy`] whenever the active
/// policy changes (`"trial"`) and at every commit (`"trial-commit"` — a
/// re-commit of the incumbent is still an observable decision).
pub struct PolicySwitchController {
    switcher: PolicySwitcher,
}

impl PolicySwitchController {
    /// Windows are validated ([`ControllerError::BadPolicyWindows`]) —
    /// construction never panics (the PR 3 contract).
    pub fn new(trial_window: u64, commit_period: u64) -> Result<Self, ControllerError> {
        Ok(PolicySwitchController { switcher: PolicySwitcher::new(trial_window, commit_period)? })
    }

    /// Completed trial→commit cycles (observability/tests).
    pub fn cycles(&self) -> u64 {
        self.switcher.cycles
    }
}

impl Controller for PolicySwitchController {
    fn name(&self) -> &'static str {
        "policy-switch"
    }

    fn observe(&mut self, ctx: &ControlCtx<'_>) -> Vec<ControlDecision> {
        let prev = self.switcher.current();
        let cycles_before = self.switcher.cycles;
        self.switcher.observe(ctx.metrics.loss);
        let cur = self.switcher.current();
        let committed = self.switcher.cycles > cycles_before;
        if cur != prev || committed {
            vec![ControlDecision {
                by: "policy-switch",
                reason: if committed { "trial-commit" } else { "trial" },
                action: ControlAction::SwitchSelectionPolicy(cur),
            }]
        } else {
            Vec::new()
        }
    }
}

// ---------------------------------------------------------------------------
// Typed errors + the registry table (the config/CLI surface).
// ---------------------------------------------------------------------------

/// A controller configuration the builder refused — lifted into the
/// Session builder's typed-error surface as
/// [`ConfigError::Controller`](crate::coordinator::session::ConfigError).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ControllerError {
    /// `--controller` spec naming no registry entry (lists valid names).
    UnknownController { spec: String },
    /// STAR/VAR trial/commit windows violating
    /// `trial_window >= 2 && commit_period >= trial_window` (was an
    /// `assert!` in `PolicySwitcher::new`).
    BadPolicyWindows { trial_window: u64, commit_period: u64 },
    /// A CR-adapting controller with an uncompressed strategy: there is
    /// no compression ratio to adapt.
    NeedsCompression { controller: &'static str, strategy: String },
}

impl fmt::Display for ControllerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ControllerError::UnknownController { spec } => write!(
                f,
                "unknown controller `{spec}` (valid: {})",
                controller_names().collect::<Vec<_>>().join(", ")
            ),
            ControllerError::BadPolicyWindows { trial_window, commit_period } => write!(
                f,
                "policy windows must satisfy trial_window >= 2 and commit_period >= \
                 trial_window (got trial_window={trial_window}, commit_period={commit_period})"
            ),
            ControllerError::NeedsCompression { controller, strategy } => write!(
                f,
                "controller `{controller}` adapts the compression ratio, which requires a \
                 compressed strategy ({strategy} is uncompressed)"
            ),
        }
    }
}

impl std::error::Error for ControllerError {}

/// One controller registry row: a name, a one-line summary (usage/help
/// text) and a constructor reading the relevant knobs off the serialized
/// [`TrainConfig`] (MOO bounds come from [`CrControl::Adaptive`] when
/// present, defaults + the run seed otherwise).
pub struct ControllerEntry {
    pub name: &'static str,
    pub summary: &'static str,
    pub build: fn(&TrainConfig) -> Box<dyn Controller>,
}

/// The one controller-name table: `--controller` parsing, usage text and
/// the unknown-name error listing all read from here, so a new adaptation
/// policy is one new row (mirror of `STRATEGY_TABLE` / `NET_TABLE`).
pub const CONTROLLER_TABLE: &[ControllerEntry] = &[
    ControllerEntry {
        name: "static",
        summary: "no adaptation: CR and strategy stay as configured",
        build: |_| Box::new(StaticController),
    },
    ControllerEntry {
        name: "moo",
        summary: "paper §3-E: checkpointed CR-ladder exploration + NSGA-II knee point",
        build: |cfg| Box::new(MooController::new(adaptive_cfg_of(cfg))),
    },
    ControllerEntry {
        name: "gravac",
        summary: "GraVAC-style threshold ladder: walk the CR ladder on gain alone",
        build: |cfg| {
            let a = adaptive_cfg_of(cfg);
            Box::new(GravacController::new(GravacConfig {
                c_low: a.c_low,
                c_high: a.c_high,
                factor: a.factor,
                ..Default::default()
            }))
        },
    },
];

/// The MOO/ladder knobs for a registry build: the configured
/// [`CrControl::Adaptive`] bounds when present, defaults (+ run seed)
/// otherwise.
fn adaptive_cfg_of(cfg: &TrainConfig) -> AdaptiveConfig {
    match &cfg.cr {
        CrControl::Adaptive(a) => a.clone(),
        CrControl::Static(_) => AdaptiveConfig { seed: cfg.seed, ..Default::default() },
    }
}

/// Every registered controller name, in table order (usage/help text).
pub fn controller_names() -> impl Iterator<Item = &'static str> {
    CONTROLLER_TABLE.iter().map(|e| e.name)
}

/// Whether the named registry controller adapts the CR — what decides if
/// adaptive-ladder flags (`--c-low`/`--c-high`/`--probe-iters`) apply to
/// a `--controller` spec. Derived from the built controller itself (no
/// second name list to drift); unknown names answer `false` and are
/// rejected with the full listing at `build()`.
pub fn spec_adapts_cr(spec: &str) -> bool {
    CONTROLLER_TABLE
        .iter()
        .find(|e| e.name == spec)
        .is_some_and(|e| (e.build)(&TrainConfig::default()).adapts_cr())
}

/// Build a registry controller by name; the error lists every valid name.
pub fn build_controller(
    spec: &str,
    cfg: &TrainConfig,
) -> Result<Box<dyn Controller>, ControllerError> {
    match CONTROLLER_TABLE.iter().find(|e| e.name == spec) {
        Some(e) => Ok((e.build)(cfg)),
        None => Err(ControllerError::UnknownController { spec: spec.to_string() }),
    }
}

/// The controller implied by the serialized [`CrControl`] form (the
/// pre-refactor behavior): `Static` → no-op, `Adaptive` → MOO.
pub fn from_cr_control(cfg: &TrainConfig) -> Box<dyn Controller> {
    match &cfg.cr {
        CrControl::Static(_) => Box::new(StaticController),
        CrControl::Adaptive(a) => Box::new(MooController::new(a.clone())),
    }
}

/// Default STAR/VAR trial/commit windows for the `artopk-auto`
/// composition (the values the old embedded switcher used).
pub const DEFAULT_POLICY_WINDOWS: (u64, u64) = (10, 50);

/// Compose `primary` with whatever extra controllers the configured
/// strategy calls for — today: the STAR/VAR [`PolicySwitchController`]
/// (at the given trial/commit windows) when the strategy is
/// `artopk-auto`. THE one place the stack shape is decided;
/// `SessionBuilder::build` and [`default_stack`] both call it.
pub fn compose_for_strategy(
    primary: Box<dyn Controller>,
    cfg: &TrainConfig,
    windows: (u64, u64),
) -> Result<Box<dyn Controller>, ControllerError> {
    if matches!(cfg.strategy, Strategy::ArTopkAuto { .. }) {
        let policy = PolicySwitchController::new(windows.0, windows.1)?;
        Ok(Box::new(CompositeController::pair(primary, Box::new(policy))))
    } else {
        Ok(primary)
    }
}

/// The full default controller stack for a config: the CR controller
/// implied by [`CrControl`], composed via [`compose_for_strategy`] at
/// [`DEFAULT_POLICY_WINDOWS`] — what `SessionBuilder::build` uses when no
/// explicit controller/spec/windows override it.
pub fn default_stack(cfg: &TrainConfig) -> Box<dyn Controller> {
    compose_for_strategy(from_cr_control(cfg), cfg, DEFAULT_POLICY_WINDOWS)
        .expect("default windows valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::CollectiveKind;

    fn ctx(m: &StepMetrics) -> ControlCtx<'_> {
        ControlCtx {
            metrics: m,
            net_changed: false,
            probed: LinkParams::from_ms_gbps(4.0, 20.0),
            cur_cr: 0.05,
            model_bytes: 4e6,
            n_workers: 4,
            compressed: true,
            straggler_factor: 1.0,
            active_workers: 4,
        }
    }

    fn metrics(step: u64, loss: f64) -> StepMetrics {
        StepMetrics {
            step,
            epoch: step as f64 / 10.0,
            loss,
            t_compute: 0.01,
            t_comp: 0.001,
            t_sync: 0.02,
            collective: CollectiveKind::ArTopkRing,
            cr: 0.05,
            selected_rank: Some(0),
            gain: 0.9,
            alpha_ms: 4.0,
            bw_gbps: 20.0,
        }
    }

    #[test]
    fn table_names_unique_and_build() {
        let cfg = TrainConfig::default();
        let mut seen = std::collections::BTreeSet::new();
        for e in CONTROLLER_TABLE {
            assert!(seen.insert(e.name), "duplicate controller name {}", e.name);
            let c = (e.build)(&cfg);
            assert_eq!(c.name(), e.name, "table name must match Controller::name");
            assert!(!e.summary.is_empty());
        }
        assert!(build_controller("static", &cfg).is_ok());
        let err = build_controller("nope", &cfg).unwrap_err();
        assert!(matches!(err, ControllerError::UnknownController { .. }));
        let msg = err.to_string();
        assert!(msg.contains("static") && msg.contains("moo") && msg.contains("gravac"), "{msg}");
    }

    /// The CLI's "do adaptive-ladder flags apply?" question is answered
    /// by the built controllers themselves — no second name list.
    #[test]
    fn spec_adapts_cr_follows_the_built_controllers() {
        assert!(!spec_adapts_cr("static"));
        assert!(spec_adapts_cr("moo"));
        assert!(spec_adapts_cr("gravac"));
        assert!(!spec_adapts_cr("nope"), "unknown names answer false, rejected at build()");
    }

    #[test]
    fn static_controller_never_decides() {
        let mut c = StaticController;
        let m = metrics(0, 1.0);
        assert!(c.observe(&ctx(&m)).is_empty());
        assert!(!c.adapts_cr());
        assert!(c.initial_cr().is_none());
    }

    /// The ported trial/commit behavior: policy flips to VAR after the
    /// STAR trial window, and the end of the VAR trial commits a winner —
    /// each an observable decision with the right reason tag.
    #[test]
    fn policy_switch_controller_trials_then_commits() {
        let mut c = PolicySwitchController::new(5, 20).unwrap();
        let mut decisions = Vec::new();
        for step in 0..10u64 {
            // STAR improves fast, VAR is flat -> STAR must win the commit.
            let loss = if step < 5 { 1.0 - 0.1 * step as f64 } else { 0.6 };
            let m = metrics(step, loss);
            decisions.extend(c.observe(&ctx(&m)));
        }
        assert_eq!(decisions.len(), 2, "{decisions:?}");
        assert_eq!(decisions[0].reason, "trial");
        assert_eq!(
            decisions[0].action,
            ControlAction::SwitchSelectionPolicy(SelectionPolicy::Var)
        );
        assert_eq!(decisions[1].reason, "trial-commit");
        assert_eq!(
            decisions[1].action,
            ControlAction::SwitchSelectionPolicy(SelectionPolicy::Star)
        );
        assert_eq!(c.cycles(), 1);
    }

    #[test]
    fn policy_windows_validated_not_panicking() {
        assert!(PolicySwitchController::new(2, 2).is_ok(), "boundary is valid");
        assert_eq!(
            PolicySwitchController::new(1, 10).err(),
            Some(ControllerError::BadPolicyWindows { trial_window: 1, commit_period: 10 })
        );
        assert_eq!(
            PolicySwitchController::new(5, 4).err(),
            Some(ControllerError::BadPolicyWindows { trial_window: 5, commit_period: 4 })
        );
    }

    /// Composite: decisions concatenate in order; exploration results
    /// route back by the requesting decision's `by` tag.
    #[test]
    fn composite_routes_exploration_results() {
        struct Wants;
        impl Controller for Wants {
            fn name(&self) -> &'static str {
                "wants"
            }
            fn observe(&mut self, _ctx: &ControlCtx<'_>) -> Vec<ControlDecision> {
                vec![ControlDecision {
                    by: "wants",
                    reason: "test",
                    action: ControlAction::RequestExploration(ExplorationRequest {
                        candidates: vec![0.1, 0.01],
                        iters: 1,
                    }),
                }]
            }
            fn on_exploration(&mut self, res: &ExplorationOutcome) -> Vec<ControlDecision> {
                vec![ControlDecision {
                    by: "wants",
                    reason: "test",
                    action: ControlAction::SetCr(res.profiles.first().map_or(0.5, |p| p.cr)),
                }]
            }
        }
        let mut c = CompositeController::pair(Box::new(StaticController), Box::new(Wants));
        let m = metrics(0, 1.0);
        let ds = c.observe(&ctx(&m));
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].by, "wants");
        let out = ExplorationOutcome {
            by: "wants",
            reason: "test",
            probed: LinkParams::from_ms_gbps(1.0, 10.0),
            profiles: vec![crate::moo::problem::CandidateProfile {
                cr: 0.07,
                t_comp: 0.0,
                t_sync: 0.01,
                gain: 0.8,
            }],
        };
        let follow = c.on_exploration(&out);
        assert_eq!(follow.len(), 1);
        assert_eq!(follow[0].action, ControlAction::SetCr(0.07));
        // A result tagged for nobody is dropped, not misrouted.
        assert!(c.on_exploration(&ExplorationOutcome { by: "ghost", ..out }).is_empty());
    }
}
