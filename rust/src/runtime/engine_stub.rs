//! Stub [`Engine`] for builds without the `pjrt` feature: the API of
//! `engine.rs` minus the `xla` dependency. Construction fails at runtime
//! with an actionable message, so simulator-only binaries link and run
//! while anything that actually needs PJRT reports why it can't.

use anyhow::{bail, Result};

const NO_PJRT: &str =
    "flexcomm was built without the `pjrt` feature; rebuild with `--features pjrt` \
     (requires the vendored `xla` crate and its xla_extension libraries)";

/// Stand-in for the PJRT CPU client wrapper.
pub struct Engine {
    _private: (),
}

impl Engine {
    /// Always fails in non-`pjrt` builds.
    pub fn cpu() -> Result<Engine> {
        bail!("{NO_PJRT}")
    }

    pub fn platform(&self) -> String {
        "unavailable (pjrt feature disabled)".to_string()
    }

    /// Always fails in non-`pjrt` builds.
    pub fn load(&self, _path: &str) -> Result<Executable> {
        bail!("{NO_PJRT}")
    }
}

/// Stand-in for a compiled computation (never constructible here).
pub struct Executable {
    pub name: String,
}
