#!/usr/bin/env bash
# flexcomm verify gate (DESIGN.md §6):
#   1. tier-1: release build + full test suite (unit, integration, doctests)
#   2. rustfmt drift check
#   3. rustdoc with warnings denied — broken intra-doc links (the old
#      "DESIGN.md referenced but missing" class of rot) fail fast here
#
# Usage: scripts/verify.sh            (from the repo root)
#        FLEXCOMM_BENCH_FAST=1 is respected by the benches, not needed here.
set -uo pipefail
cd "$(dirname "$0")/.."

status=0
step() {
    echo
    echo "==> $*"
    if ! "$@"; then
        echo "FAILED: $*"
        status=1
    fi
}

step cargo build --release
step cargo test -q
step cargo fmt --check
step env RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if [ "$status" -ne 0 ]; then
    echo
    echo "verify: FAILED (see steps above)"
else
    echo
    echo "verify: OK"
fi
exit "$status"
