"""L1 Pallas kernel: MXU-tiled matmul, the dense-layer hot spot of the L2 model.

Hardware adaptation (see DESIGN.md §7): the paper's GPU training relies on
cuBLAS threadblock tiling through shared memory.  On TPU the analogue is a
BlockSpec-scheduled HBM->VMEM pipeline feeding the 128x128 MXU systolic
array.  The grid is (m/bm, n/bn, k/bk) with the contraction axis innermost so
a single VMEM-resident output block accumulates across the k steps
(double-buffered input blocks stream past it).

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO.  Structure (block
shapes, accumulation order, one-pass fusion) is what we optimize; CPU
wallclock of interpret mode is NOT a TPU proxy.

Autodiff: ``pl.pallas_call`` has no VJP, so ``matmul`` carries a
``jax.custom_vjp`` whose forward and backward passes all route through the
same Pallas kernel (dx = dy @ w^T, dw = x^T @ dy) — the backward pass of the
L2 model therefore exercises the kernel as well.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tiles. 128 matches the MXU systolic array edge; VMEM
# footprint per step = (bm*bk + bk*bn + bm*bn) * 4B = 192 KiB at 128^3,
# comfortably inside the ~16 MiB/core VMEM with room for double buffering.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output block; accumulates over the innermost k grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(x: int, b: int) -> int:
    return -(-x // b) * b


def _matmul_padded(x, w, bm, bn, bk):
    """Pallas call on shapes already padded to block multiples."""
    m, k = x.shape
    _, n = w.shape
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def matmul_fwd_only(x, w, *, bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    """Tiled matmul without the custom-vjp wrapper (used by tests/bench)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    out = _matmul_padded(xp, wp, bm, bn, bk)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x, w):
    """Differentiable tiled-Pallas matmul: (m,k) @ (k,n) -> (m,n)."""
    return matmul_fwd_only(x, w)


def _mm_fwd(x, w):
    return matmul_fwd_only(x, w), (x, w)


def _mm_bwd(res, dy):
    x, w = res
    # Both grads go through the same Pallas kernel.
    dx = matmul_fwd_only(dy, w.T)
    dw = matmul_fwd_only(x.T, dy)
    return dx, dw


matmul.defvjp(_mm_fwd, _mm_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_jit(x, w, *, bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    return matmul_fwd_only(x, w, bm=bm, bn=bn, bk=bk)


def vmem_bytes(bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K, dtype_bytes=4):
    """Estimated VMEM working set per grid step (for DESIGN/EXPERIMENTS §Perf)."""
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes


def mxu_utilization_estimate(m, n, k, bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    """Fraction of MXU issue slots doing useful work, from padding overhead.

    The MXU processes full 128x128 tiles; edge blocks waste the padded
    fraction.  This is the structural estimate recorded in EXPERIMENTS §Perf
    (interpret mode gives no hardware counters).
    """
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    useful = m * n * k
    issued = mp * np_ * kp
    return useful / issued
