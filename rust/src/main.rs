//! flexcomm CLI — leader entrypoint.
//!
//! Subcommands:
//!   train      run a training configuration (flags or --config file)
//!   sweep      run a model x strategy x net x controller grid concurrently
//!   cost       print α-β cost-model tables (Table I / II / VI, Fig 5)
//!   schedule   print a network schedule (Fig 6) and probe it
//!   info       artifacts + PJRT platform info
//!
//! Examples:
//!   flexcomm train --model mlp --strategy artopk-star --cr 0.01 --steps 200
//!   flexcomm train --model matreg --strategy flexible --adaptive --net c2
//!   flexcomm train --strategy flexible --net c2-hostile --progress --out run.csv
//!   flexcomm train --net trace:examples/traces/c2_measured.csv
//!   flexcomm train --net c1 --jitter 0.05 --congestion 0.1,8
//!   flexcomm sweep --models mlp,matreg --nets c1,c2,flaky --target-acc 0.6
//!   flexcomm sweep --smoke
//!   flexcomm cost --table2
//!   flexcomm schedule --name c2 --epochs 50

use anyhow::{bail, Context, Result};
use flexcomm::coordinator::controller::{controller_names, spec_adapts_cr, AdaptiveConfig};
use flexcomm::coordinator::observer::{CsvSink, ProgressPrinter};
use flexcomm::coordinator::session::Session;
use flexcomm::coordinator::sweep::SweepSpec;
use flexcomm::coordinator::trainer::{CrControl, Strategy};
use flexcomm::coordinator::worker::{ComputeModel, GradSource};
use flexcomm::models::{build_model, model_names};
use flexcomm::netsim::cost_model::{self, LinkParams};
use flexcomm::netsim::model::{parse_spec, scenario_names, NetworkModel};
use flexcomm::netsim::modifiers::{
    AsymmetricDegrade, CongestionEpisodes, Diurnal, Flapping, Jitter, TwoLevel,
};
use flexcomm::netsim::probe::Probe;
use flexcomm::netsim::schedule::NetSchedule;
use flexcomm::runtime::{find_artifacts_dir, Engine, ModelArtifacts, PjrtModel};
use flexcomm::util::cli::Args;
use flexcomm::util::config::Config;
use flexcomm::util::table::{fmt_ms, fmt_pct, Table};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("cost") => cmd_cost(&args),
        Some("schedule") => cmd_schedule(&args),
        Some("info") => cmd_info(),
        Some(other) => bail!("unknown subcommand `{other}` (train|sweep|cost|schedule|info)"),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    // Strategy and network names print from the SAME tables the parsers
    // use (Strategy::parse / netsim::model::NET_TABLE), so help cannot
    // drift.
    println!(
        "flexcomm — AR-Topk + flexible collectives + pluggable adaptation controllers\n\
         usage: flexcomm <train|sweep|cost|schedule|info> [--flags]\n\
         models:      --model {}|synthetic:<dim>\n\
         strategies:  {}\n\
         networks:    --net static|{}|trace:<path>\n\
         modifiers:   --jitter F  --congestion P,FACTOR  --diurnal AMP,PERIOD\n\
                      --flap PERIOD,DOWN,FACTOR  --asym AMULT,BWDIV  --net-seed N\n\
         controllers: --controller {} (--adaptive = --controller moo)\n\
         fleet mode:  --fleet-n N [--fleet-mbytes MB] (cost-only, 1024-16384 workers)\n\
         sweep mode:  flexcomm sweep --models A,B --strategies .. --nets .. \n\
                      --controllers .. [--in-flight K] [--target-acc F] [--smoke]\n\
         try:   flexcomm train --model mlp --strategy artopk-star --cr 0.01\n\
                flexcomm train --strategy flexible --net c2-hostile --progress\n\
                flexcomm train --strategy flexible --net c2 --controller gravac\n\
                flexcomm train --fleet-n 4096 --net hetero --steps 100\n\
                flexcomm sweep --models mlp,matreg --target-acc 0.6\n\
                flexcomm cost --table1\n\
                flexcomm schedule --name c2-congested",
        model_names().collect::<Vec<_>>().join("|"),
        Strategy::names().collect::<Vec<_>>().join("|"),
        scenario_names().collect::<Vec<_>>().join("|"),
        controller_names().collect::<Vec<_>>().join("|"),
    );
}

/// Build a gradient source by model spec: [`MODEL_TABLE`] names and
/// `synthetic:<dim>` resolve through the registry
/// ([`flexcomm::models::build_model`]); any other name is looked up as an
/// AOT artifact for the PJRT runtime.
fn build_source(model: &str, seed: u64) -> Result<Box<dyn GradSource>> {
    if model_names().any(|n| n == model) || model.starts_with("synthetic:") {
        return Ok(build_model(model, seed)?);
    }
    let dir = find_artifacts_dir()?;
    let arts = ModelArtifacts::load(&dir, model)?;
    let engine = Engine::cpu()?;
    Ok(Box::new(PjrtModel::load(&engine, arts, seed)?))
}

/// `flexcomm sweep`: expand a model x strategy x net x controller grid and
/// run every cell concurrently over ONE shared worker pool, then print the
/// ranked time-to-accuracy table and emit BENCH_sweep.json + CSV.
/// `--smoke` runs the verify.sh gate grid and enforces full coverage with
/// every cell above its model's chance floor.
fn cmd_sweep(args: &Args) -> Result<()> {
    let mut spec = if args.flag("smoke") { SweepSpec::smoke() } else { SweepSpec::default() };
    let axis = |flag: &str, cur: &[String]| -> Vec<String> {
        match args.opt(flag) {
            Some(s) => s
                .split(',')
                .map(|x| x.trim().to_string())
                .filter(|x| !x.is_empty())
                .collect(),
            None => cur.to_vec(),
        }
    };
    spec.models = axis("models", &spec.models);
    spec.strategies = axis("strategies", &spec.strategies);
    spec.nets = axis("nets", &spec.nets);
    spec.controllers = axis("controllers", &spec.controllers);
    spec.workers = args.usize_or("workers", spec.workers)?;
    spec.steps = args.u64_or("steps", spec.steps)?;
    spec.steps_per_epoch = args.u64_or("steps-per-epoch", spec.steps_per_epoch)?;
    spec.lr = args.f64_or("lr", spec.lr as f64)? as f32;
    spec.momentum = args.f64_or("momentum", spec.momentum as f64)? as f32;
    spec.cr = args.f64_or("cr", spec.cr)?;
    spec.eval_every = args.u64_or("eval-every", spec.eval_every)?;
    spec.seed = args.u64_or("seed", spec.seed)?;
    spec.threads = args.usize_or("threads", spec.threads)?;
    spec.in_flight = args.usize_or("in-flight", spec.in_flight)?;
    spec.target_acc = args.f64_or("target-acc", spec.target_acc)?;
    println!(
        "flexcomm sweep: {} models x {} strategies x {} nets x {} controllers = {} cells \
         (window {}, pool threads {})",
        spec.models.len(),
        spec.strategies.len(),
        spec.nets.len(),
        spec.controllers.len(),
        spec.models.len() * spec.strategies.len() * spec.nets.len() * spec.controllers.len(),
        spec.in_flight,
        spec.threads,
    );
    let report = spec.run()?;
    report.print_ranked();
    let (json, csv) = report.write_files(
        &args.str_or("out-json", "BENCH_sweep.json"),
        &args.str_or("out-csv", "BENCH_sweep.csv"),
    )?;
    let (steps, evals, cells) = report.progress.snapshot();
    println!(
        "wrote {json} and {csv} ({cells} cells, {steps} steps, {evals} evals, {} failed)",
        report.failed_cells()
    );
    if args.flag("smoke") {
        report
            .verify_full_coverage(&spec)
            .map_err(|e| anyhow::anyhow!("sweep smoke gate: {e}"))?;
        println!("sweep smoke gate: full row coverage, every cell above its chance floor");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    // Optional config file; flags override.
    let mut cfgfile = Config::default();
    if let Some(path) = args.opt("config") {
        cfgfile = Config::load(path)?;
    }
    let model = args.str_or("model", &cfgfile.str_or("train.model", "host-mlp"));
    let seed = args.u64_or("seed", cfgfile.int_or("train.seed", 0) as u64)?;
    let strategy = Strategy::parse(&args.str_or(
        "strategy",
        &cfgfile.str_or("train.strategy", "flexible"),
    ))?;
    let steps = args.u64_or("steps", cfgfile.int_or("train.steps", 200) as u64)?;
    let spe = args.u64_or("steps-per-epoch", cfgfile.int_or("train.steps_per_epoch", 50) as u64)?;
    let epochs = steps as f64 / spe.max(1) as f64;

    // Network environment (DESIGN.md §9): `--net <scenario|trace:path>`
    // resolves through the NET_TABLE registry; the legacy `--schedule`
    // flag (static|c1|c2) still works, and `--net static` honours the
    // explicit --alpha-ms/--bw-gbps link. Modifier flags then compose
    // wrappers over the base model in a fixed, documented order.
    let net_spec = match args.opt("net") {
        Some(s) => Some(s.to_string()),
        None => {
            let from_file = cfgfile.str_or("net.model", "");
            if from_file.is_empty() {
                None
            } else {
                Some(from_file)
            }
        }
    };
    let static_link = LinkParams::from_ms_gbps(
        args.f64_or("alpha-ms", cfgfile.float_or("net.alpha_ms", 4.0))?,
        args.f64_or("bw-gbps", cfgfile.float_or("net.bw_gbps", 20.0))?,
    );
    let mut net: Box<dyn NetworkModel> = match net_spec.as_deref() {
        Some("static") => Box::new(NetSchedule::static_link(static_link)),
        Some(spec) => parse_spec(spec, epochs)?,
        None => match args
            .str_or("schedule", &cfgfile.str_or("net.schedule", "static"))
            .as_str()
        {
            "static" => Box::new(NetSchedule::static_link(static_link)),
            name => Box::new(NetSchedule::preset(name, epochs)?),
        },
    };

    // Modifier wrappers, applied inside-out in this order: jitter ->
    // congestion -> diurnal -> flap -> asym (DESIGN.md §9 determinism
    // contract; stochastic wrappers get distinct seeds derived from
    // --net-seed).
    let net_seed = args.u64_or("net-seed", seed)?;
    if let Some(frac) = args.opt("jitter") {
        let frac: f64 = frac.parse().context("--jitter <frac>")?;
        net = Box::new(Jitter::wrap(net, frac, net_seed)?);
    }
    if args.opt("congestion").is_some() {
        let v = args.f64_list_or("congestion", &[])?;
        let &[prob, factor] = v.as_slice() else { bail!("--congestion <prob,factor>") };
        net = Box::new(CongestionEpisodes::wrap(net, prob, factor, net_seed ^ 0xC0)?);
    }
    if args.opt("diurnal").is_some() {
        let v = args.f64_list_or("diurnal", &[])?;
        let &[amp, period] = v.as_slice() else { bail!("--diurnal <amplitude,period_epochs>") };
        net = Box::new(Diurnal::wrap(net, amp, period)?);
    }
    if args.opt("flap").is_some() {
        let v = args.f64_list_or("flap", &[])?;
        let &[period, down, factor] = v.as_slice() else {
            bail!("--flap <period_epochs,down_frac,factor>")
        };
        net = Box::new(Flapping::wrap(net, period, down, factor)?);
    }
    if args.opt("asym").is_some() {
        let v = args.f64_list_or("asym", &[])?;
        let &[amult, bwdiv] = v.as_slice() else { bail!("--asym <alpha_mult,bw_div>") };
        net = Box::new(AsymmetricDegrade::wrap(net, amult, bwdiv)?);
    }

    // Optional two-level topology overlay: a fast fixed intra-node link
    // under the (modified) inter-node model (--workers-per-node > 1).
    let wpn = args.usize_or(
        "workers-per-node",
        cfgfile.int_or("net.workers_per_node", 1) as usize,
    )?;
    if wpn > 1 {
        net = Box::new(TwoLevel::wrap(
            net,
            LinkParams::from_ms_gbps(
                args.f64_or("intra-ms", cfgfile.float_or("net.intra_alpha_ms", 0.01))?,
                args.f64_or("intra-gbps", cfgfile.float_or("net.intra_bw_gbps", 100.0))?,
            ),
            wpn,
        )?);
    }

    // Fleet cost mode (DESIGN.md §11): `--fleet-n N` prices a full run
    // for an N-worker fleet through the event-driven FleetSim instead of
    // the numeric trainer — per-worker links, stragglers and churn priced
    // honestly, no per-worker dense state, so 1024-16384 workers are fine.
    let fleet_n = args.usize_or("fleet-n", cfgfile.int_or("train.fleet_n", 0) as usize)?;
    if fleet_n > 0 {
        return run_fleet(args, &cfgfile, fleet_n, steps, spe, seed, net);
    }

    // Control plane (DESIGN.md §10): `--controller <name>` picks from the
    // CONTROLLER_TABLE registry; `--adaptive` remains the shorthand that
    // implies the `moo` controller via CrControl::Adaptive. For any
    // CR-adapting controller spec (asked of the registry itself, so a new
    // table row automatically participates), the adaptive bounds flags
    // (--c-low/--c-high/--probe-iters) are honoured too.
    let controller_spec = match args.opt("controller") {
        Some(s) => Some(s.to_string()),
        None => {
            let from_file = cfgfile.str_or("control.controller", "");
            if from_file.is_empty() {
                None
            } else {
                Some(from_file)
            }
        }
    };
    let wants_adaptive_bounds = args.flag("adaptive")
        || cfgfile.bool_or("compress.adaptive", false)
        || controller_spec.as_deref().is_some_and(spec_adapts_cr);
    let cr = if wants_adaptive_bounds {
        CrControl::Adaptive(AdaptiveConfig {
            c_low: args.f64_or("c-low", cfgfile.float_or("compress.c_low", 0.001))?,
            c_high: args.f64_or("c-high", cfgfile.float_or("compress.c_high", 0.1))?,
            probe_iters: args.u64_or("probe-iters", 10)?,
            seed,
            ..Default::default()
        })
    } else {
        CrControl::Static(args.f64_or("cr", cfgfile.float_or("compress.cr", 0.01))?)
    };

    println!("flexcomm train: model={model} strategy={strategy:?} steps={steps}");
    // The validating builder (DESIGN.md §8): misconfigurations surface
    // here as typed errors, not panics mid-run.
    let mut builder = Session::builder()
        .workers(args.usize_or("workers", cfgfile.int_or("train.workers", 8) as usize)?)
        .steps(steps)
        .steps_per_epoch(spe)
        .lr(args.f64_or("lr", cfgfile.float_or("train.lr", 0.1))? as f32)
        .momentum(args.f64_or("momentum", cfgfile.float_or("train.momentum", 0.9))? as f32)
        .weight_decay(args.f64_or("wd", cfgfile.float_or("train.weight_decay", 0.0))? as f32)
        .strategy(strategy)
        .cr(cr)
        .network_boxed(net)
        .compute(ComputeModel::with_jitter(
            args.f64_or("compute-ms", cfgfile.float_or("train.compute_ms", 20.0))? * 1e-3,
            0.05,
        ))
        .msg_scale(args.f64_or("msg-scale", 1.0)?)
        .comp_scale(args.f64_or("comp-scale", 1.0)?)
        .eval_every(args.u64_or("eval-every", spe)?)
        .seed(seed)
        // Worker execution engine: 0 = all available cores (default);
        // numerics are identical for every value (DESIGN.md §7).
        .threads(args.usize_or("threads", cfgfile.int_or("train.threads", 0) as usize)?)
        .source(build_source(&model, seed)?);
    if let Some(spec) = &controller_spec {
        builder = builder.controller_spec(spec);
    }
    if args.flag("progress") {
        builder = builder.observer(Box::new(ProgressPrinter::every(spe)));
    }
    // Validate BEFORE opening the sink: CsvSink truncates its target on
    // creation, and a rejected config must not clobber previous results.
    let mut session = builder.build()?;
    let out = args.opt("out");
    if let Some(path) = out {
        // Stream rows as they happen: a killed run still leaves a CSV,
        // tagged with the scenario identity it ran under.
        let scenario = session.network_describe();
        session = session.observer(Box::new(CsvSink::create_with_scenario(path, &scenario)?));
    }
    let report = session.run();

    let s = report.summary();
    let mut tab = Table::new(["metric", "value"]);
    tab.row(["model", &report.model]);
    tab.row(["strategy", &report.strategy]);
    tab.row(["network", &report.network]);
    tab.row(["controller", &report.controller]);
    tab.row(["steps", &s.steps.to_string()]);
    tab.row(["t_step (ms)", &fmt_ms(s.mean_step_s)]);
    tab.row(["  t_compute (ms)", &fmt_ms(s.mean_compute_s)]);
    tab.row(["  t_comp (ms)", &fmt_ms(s.mean_comp_s)]);
    tab.row(["  t_sync (ms)", &fmt_ms(s.mean_sync_s)]);
    tab.row(["mean gain", &format!("{:.4}", s.mean_gain)]);
    tab.row(["final loss", &format!("{:.4}", s.final_loss)]);
    if let Some(acc) = report.final_accuracy() {
        tab.row(["final accuracy", &fmt_pct(acc)]);
    }
    tab.row(["virtual time (s)", &format!("{:.2}", report.virtual_time_s)]);
    tab.row(["explore overhead (s)", &format!("{:.2}", report.explore_overhead_s)]);
    tab.print();

    if let Some(path) = out {
        println!("wrote {path}");
    }
    Ok(())
}

/// `flexcomm train --fleet-n N`: the event-driven fleet cost engine.
/// Message size comes from `--fleet-mbytes` (a scalar — fleet mode never
/// allocates gradient-shaped state), the CR from the usual `--cr`.
fn run_fleet(
    args: &Args,
    cfgfile: &Config,
    fleet_n: usize,
    steps: u64,
    spe: u64,
    seed: u64,
    net: Box<dyn NetworkModel>,
) -> Result<()> {
    use flexcomm::coordinator::fleet::{FleetConfig, FleetSim};
    let scenario = net.describe();
    let cfg = FleetConfig {
        n_workers: fleet_n,
        steps,
        steps_per_epoch: spe.max(1),
        model_bytes: args.f64_or("fleet-mbytes", 102.4)? * 1e6,
        cr: args.f64_or("cr", cfgfile.float_or("compress.cr", 0.01))?,
        net,
        compute: ComputeModel::with_jitter(
            args.f64_or("compute-ms", cfgfile.float_or("train.compute_ms", 20.0))? * 1e-3,
            0.05,
        ),
        seed,
    };
    println!("flexcomm fleet: n={fleet_n} steps={steps} net={scenario}");
    let r = FleetSim::new(cfg).run();
    let mut tab = Table::new(["metric", "value"]);
    tab.row(["network", &scenario]);
    tab.row(["workers", &r.n_workers.to_string()]);
    tab.row(["steps", &r.steps.to_string()]);
    tab.row(["virtual time (s)", &format!("{:.2}", r.virtual_time_s)]);
    tab.row(["  compute (s)", &format!("{:.2}", r.compute_s)]);
    tab.row(["  sync (s)", &format!("{:.2}", r.comm_s)]);
    tab.row(["  catch-up (s)", &format!("{:.2}", r.catchup_s)]);
    tab.row(["membership changes", &r.membership_changes.to_string()]);
    tab.row(["min active", &r.min_active.to_string()]);
    tab.row(["stat efficiency", &format!("{:.4}", r.stat_efficiency)]);
    tab.row(["est steps to parity", &format!("{:.1}", r.est_steps_to_parity)]);
    tab.row(["straggler mean/max", &format!(
        "{:.2} / {:.2}",
        r.sampled_mean_straggler, r.sampled_max_straggler
    )]);
    tab.row(["slow-link share", &fmt_pct(r.slow_link_share)]);
    for (name, count) in &r.collective_counts {
        tab.row([&format!("steps via {name}"), &count.to_string()]);
    }
    tab.row(["peak state (f64 slots)", &r.peak_state_f64s.to_string()]);
    tab.print();
    // The O(n)-not-O(n*dim) contract, grep-able by scripts/verify.sh.
    println!(
        "fleet state: peak {} f64 slots for n={} (O(n) bound {})",
        r.peak_state_f64s,
        r.n_workers,
        2 * r.n_workers + 64
    );
    Ok(())
}

fn cmd_cost(args: &Args) -> Result<()> {
    let n = args.usize_or("workers", 8)?;
    if args.flag("table1") {
        let l = LinkParams::from_ms_gbps(args.f64_or("alpha-ms", 1.0)?, args.f64_or("bw-gbps", 10.0)?);
        let m = args.f64_or("mbytes", 400.0)? * 1e6;
        let mut t = Table::new(["Operation", "BW Complexity", "Cost (ms)"]);
        t.row(["PS (Star)", "O(MN)", &fmt_ms(cost_model::ps_star(l, m, n))]);
        t.row(["Ring-AR", "O(M)", &fmt_ms(cost_model::ring_allreduce(l, m, n))]);
        t.row(["Tree-AR", "O(M logN)", &fmt_ms(cost_model::tree_allreduce(l, m, n))]);
        t.row(["Broadcast", "O(M logN)", &fmt_ms(cost_model::broadcast(l, m, n))]);
        t.row(["Allgather", "O(MN)", &fmt_ms(cost_model::allgather(l, m, n))]);
        t.print();
        return Ok(());
    }
    // Default: the flexible-selection view for one (α, β, M, N).
    let l = LinkParams::from_ms_gbps(args.f64_or("alpha-ms", 1.0)?, args.f64_or("bw-gbps", 10.0)?);
    let m = args.f64_or("mbytes", 100.0)? * 1e6;
    let mut t = Table::new(["CR", "AG (ms)", "ART-Ring (ms)", "ART-Tree (ms)", "chosen"]);
    for cr in args.f64_list_or("crs", &[0.1, 0.01, 0.001])? {
        let ag = cost_model::ag_topk(l, m, n, cr);
        let ring = cost_model::art_ring(l, m, n, cr);
        let tree = cost_model::art_tree(l, m, n, cr);
        let chosen = cost_model::optimal_collective(l, m, n, cr).name();
        t.row([
            format!("{cr}"),
            fmt_ms(ag),
            fmt_ms(ring),
            fmt_ms(tree),
            chosen.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let name = args.str_or("name", "c1");
    let epochs = args.f64_or("epochs", 50.0)?;
    // Any registry scenario or trace:<path> works here; bare NetSchedule
    // presets additionally print their exact Fig 6 breakpoints.
    let model = parse_spec(&name, epochs)?;
    println!("scenario: {}", model.describe());
    match NetSchedule::preset(&name, epochs) {
        Ok(sched) => {
            let mut t = Table::new(["epoch", "alpha (ms)", "bandwidth (Gbps)"]);
            for p in sched.phases() {
                t.row([
                    format!("{:.0}+", p.from_epoch),
                    format!("{:.1}", p.link.alpha_ms()),
                    format!("{:.1}", p.link.bw_gbps()),
                ]);
            }
            t.print();
        }
        Err(_) => {
            // Composite/trace model: sample the ground truth instead.
            let mut t = Table::new(["epoch", "alpha (ms)", "bandwidth (Gbps)"]);
            let step = (epochs / 20.0).max(0.5);
            let mut e = 0.0;
            while e < epochs {
                let l = model.link_at(e);
                t.row([
                    format!("{e:.1}"),
                    format!("{:.2}", l.alpha_ms()),
                    format!("{:.2}", l.bw_gbps()),
                ]);
                e += step;
            }
            t.print();
        }
    }
    if args.flag("probe") {
        let mut probe = Probe::new(model, 0.05, args.u64_or("seed", 0)?);
        println!("\nprobed observations (5% noise):");
        let mut t = Table::new(["epoch", "alpha (ms)", "bw (Gbps)", "changed"]);
        let step = (epochs / 20.0).max(0.5);
        let mut e = 0.0;
        while e < epochs {
            let (obs, ch) = probe.measure_and_detect(e);
            t.row([
                format!("{e:.1}"),
                format!("{:.2}", obs.alpha_ms),
                format!("{:.2}", obs.bw_gbps),
                if ch { "*".to_string() } else { String::new() },
            ]);
            e += step;
        }
        t.print();
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    match find_artifacts_dir() {
        Ok(dir) => {
            println!("artifacts: {}", dir.display());
            let mut names: Vec<String> = std::fs::read_dir(&dir)?
                .filter_map(|e| e.ok())
                .filter_map(|e| {
                    e.file_name()
                        .to_str()
                        .and_then(|n| n.strip_suffix("_meta.txt").map(str::to_string))
                })
                .collect();
            names.sort();
            for n in names {
                let arts = ModelArtifacts::load(&dir, &n)?;
                println!(
                    "  {n}: kind={} params={}",
                    arts.kind(),
                    arts.param_count().map(|p| p.to_string()).unwrap_or("?".into())
                );
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
    // PJRT may be compiled out (no `pjrt` feature) — report, don't fail.
    match Engine::cpu() {
        Ok(engine) => println!("pjrt: platform={}", engine.platform()),
        Err(e) => println!("pjrt: {e}"),
    }
    Ok(())
}
