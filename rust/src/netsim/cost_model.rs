//! Closed-form α-β communication costs (paper Table I + Eqn 4) and the
//! collective-switching heuristics (Eqn 5).
//!
//! Conventions: `alpha` is per-message latency in **seconds**, `beta` is
//! **seconds per byte** (1/bandwidth), `m` is message size in **bytes**,
//! `n` is cluster size, `c` is the compression ratio (kept fraction).
//! `log` is log2 — the round count of binomial/recursive-doubling
//! algorithms.

/// Latency/bandwidth parameters of the (emulated) link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkParams {
    /// Per-message latency, seconds.
    pub alpha: f64,
    /// Inverse bandwidth, seconds per byte.
    pub beta: f64,
}

impl LinkParams {
    /// From the units the paper quotes: latency in ms, bandwidth in Gbps.
    ///
    /// ```
    /// use flexcomm::netsim::cost_model::LinkParams;
    /// let l = LinkParams::from_ms_gbps(4.0, 20.0);
    /// assert!((l.alpha - 4e-3).abs() < 1e-15);       // 4 ms in seconds
    /// assert!((l.beta - 4e-10).abs() < 1e-22);       // 8 bits / 20e9 bps
    /// assert!((l.alpha_ms() - 4.0).abs() < 1e-12);   // round-trips
    /// assert!((l.bw_gbps() - 20.0).abs() < 1e-9);
    /// ```
    pub fn from_ms_gbps(alpha_ms: f64, bw_gbps: f64) -> Self {
        assert!(alpha_ms >= 0.0 && bw_gbps > 0.0);
        LinkParams {
            alpha: alpha_ms * 1e-3,
            beta: 8.0 / (bw_gbps * 1e9),
        }
    }

    pub fn alpha_ms(&self) -> f64 {
        self.alpha * 1e3
    }

    pub fn bw_gbps(&self) -> f64 {
        8.0 / (self.beta * 1e9)
    }
}

#[inline]
fn log2f(n: usize) -> f64 {
    (n as f64).log2()
}

/// `⌈log2 n⌉` as f64 — the binomial round count for arbitrary `n` (matches
/// the simulated ops, which can't run fractional rounds).
#[inline]
fn ceil_log2f(n: usize) -> f64 {
    // Plain assert (matching prev_pow2 below): in release a debug_assert
    // would vanish and `n - 1` wraps to a 64-round "collective".
    assert!(n >= 1);
    (usize::BITS - (n - 1).leading_zeros()) as f64
}

/// Largest power of two `<= n` (the participant count after Rabenseifner's
/// non-power-of-two fold). `prev_pow2(1) == 1`.
pub fn prev_pow2(n: usize) -> usize {
    assert!(n >= 1);
    if n.is_power_of_two() {
        n
    } else {
        n.next_power_of_two() >> 1
    }
}

/// Two-level cluster topology: `workers_per_node` ranks share a fast
/// intra-node link (NVLink/PCIe class); nodes talk over a slower inter-node
/// link (the paper's `tc`-shaped TCP link). `workers_per_node == 1` is the
/// flat single-link cluster every pre-topology experiment assumed.
///
/// The α-β crossover between collectives depends on this structure (Agarwal
/// et al., *On the Utility of Gradient Compression*): a hierarchical
/// allreduce pays the slow link only `N/workers_per_node`-wide, which flips
/// the optimal dense collective on asymmetric clusters — see
/// [`hierarchical_allreduce`] and the selector's
/// [`choose_dense_topo`](crate::coordinator::selector::choose_dense_topo).
///
/// ```
/// use flexcomm::netsim::cost_model::{LinkParams, Topology};
/// let t = Topology::two_level(
///     LinkParams::from_ms_gbps(0.01, 100.0), // intra: NVLink-class
///     LinkParams::from_ms_gbps(4.0, 20.0),   // inter: shaped TCP
///     4,                                     // ranks per node
/// );
/// assert_eq!(t.nodes(8), 2);
/// assert!(!t.is_flat());
/// assert!(Topology::flat(LinkParams::from_ms_gbps(4.0, 20.0)).is_flat());
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Topology {
    /// Link between ranks on the same node.
    pub intra: LinkParams,
    /// Link between nodes — the bottleneck every flat collective rides.
    pub inter: LinkParams,
    /// Ranks per node; 1 = flat cluster (intra link unused).
    pub workers_per_node: usize,
}

impl Topology {
    /// Flat single-link cluster (the pre-topology default): every pair of
    /// ranks talks over the same `link`.
    pub fn flat(link: LinkParams) -> Self {
        Topology { intra: link, inter: link, workers_per_node: 1 }
    }

    /// Two-level cluster: `workers_per_node` ranks per node on `intra`,
    /// nodes connected by `inter`.
    pub fn two_level(intra: LinkParams, inter: LinkParams, workers_per_node: usize) -> Self {
        assert!(workers_per_node >= 1, "workers_per_node must be >= 1");
        Topology { intra, inter, workers_per_node }
    }

    /// True when the cluster degenerates to a single link.
    pub fn is_flat(&self) -> bool {
        self.workers_per_node <= 1
    }

    /// Node count for an `n`-rank cluster (`workers_per_node` must divide
    /// `n` evenly — ragged nodes are not modelled).
    pub fn nodes(&self, n: usize) -> usize {
        assert!(
            n % self.workers_per_node == 0,
            "cluster size {n} not divisible by workers_per_node {}",
            self.workers_per_node
        );
        n / self.workers_per_node
    }

    /// Scale β on both links by `s` — the `msg_scale` proxy trick
    /// (DESIGN.md §3): charging `s`× the bytes on the same link is
    /// equivalent to `β·s` with α unchanged.
    pub fn scale_beta(&self, s: f64) -> Topology {
        Topology {
            intra: LinkParams { alpha: self.intra.alpha, beta: self.intra.beta * s },
            inter: LinkParams { alpha: self.inter.alpha, beta: self.inter.beta * s },
            workers_per_node: self.workers_per_node,
        }
    }
}

/// Parameter-server (star): `2α + 2(N-1)Mβ`  — O(MN) bandwidth.
pub fn ps_star(l: LinkParams, m: f64, n: usize) -> f64 {
    2.0 * l.alpha + 2.0 * (n as f64 - 1.0) * m * l.beta
}

/// Ring allreduce: `2(N-1)α + 2((N-1)/N)Mβ` — bandwidth-optimal.
pub fn ring_allreduce(l: LinkParams, m: f64, n: usize) -> f64 {
    let nf = n as f64;
    2.0 * (nf - 1.0) * l.alpha + 2.0 * ((nf - 1.0) / nf) * m * l.beta
}

/// Tree allreduce: `2α·log(N) + 2·log(N)·Mβ`.
pub fn tree_allreduce(l: LinkParams, m: f64, n: usize) -> f64 {
    2.0 * l.alpha * log2f(n) + 2.0 * log2f(n) * m * l.beta
}

/// Recursive halving-doubling allreduce (Rabenseifner):
/// `2α·log(N) + 2((N-1)/N)Mβ` for power-of-two N — the ring's bandwidth
/// optimality at tree-like latency (log(N) α-rounds vs the ring's 2(N-1)).
///
/// Non-power-of-two N folds the `r = N - 2^⌊log2 N⌋` extra ranks into
/// partners before/after the power-of-two core, adding `2α + 2Mβ`; the
/// simulated op in [`crate::collectives::halving_doubling`] reproduces the
/// same round structure exactly.
pub fn halving_doubling_allreduce(l: LinkParams, m: f64, n: usize) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let np = prev_pow2(n) as f64;
    let mut cost = 2.0 * np.log2() * l.alpha + 2.0 * ((np - 1.0) / np) * m * l.beta;
    if np as usize != n {
        cost += 2.0 * (l.alpha + m * l.beta);
    }
    cost
}

/// Two-level hierarchical allreduce on a [`Topology`]: binomial reduce to
/// each node's leader over the intra link, ring allreduce among the
/// `L = N/w` leaders over the inter link, binomial broadcast back:
/// `2·⌈log(w)⌉(α_i + Mβ_i) + 2(L-1)α_e + 2((L-1)/L)Mβ_e`.
///
/// The intra term uses ⌈log⌉ (binomial trees run whole rounds), so this is
/// exact against the simulated op for *any* `w`. The point of the op is
/// that the slow inter link is paid only `L`-wide, so it wins on
/// fast-intra/slow-inter clusters where every flat collective is priced on
/// the bottleneck link.
pub fn hierarchical_allreduce(t: Topology, m: f64, n: usize) -> f64 {
    let w = t.workers_per_node.max(1);
    let nodes = t.nodes(n);
    2.0 * ceil_log2f(w) * (t.intra.alpha + m * t.intra.beta) + ring_allreduce(t.inter, m, nodes)
}

/// Binomial broadcast: `α·log(N) + log(N)·Mβ`.
pub fn broadcast(l: LinkParams, m: f64, n: usize) -> f64 {
    l.alpha * log2f(n) + log2f(n) * m * l.beta
}

/// Allgather: `α·log(N) + (N-1)Mβ` where `m` is the PER-WORKER contribution.
pub fn allgather(l: LinkParams, m: f64, n: usize) -> f64 {
    l.alpha * log2f(n) + (n as f64 - 1.0) * m * l.beta
}

/// Allgather of a Top-k compressed tensor (values + indices):
/// `α·log(N) + 2Mcβ(N-1)` (paper §3-D). `m` is the UNcompressed bytes.
pub fn ag_topk(l: LinkParams, m: f64, n: usize, c: f64) -> f64 {
    l.alpha * log2f(n) + 2.0 * m * c * l.beta * (n as f64 - 1.0)
}

/// AR-Topk with ring reduction (Eqn 4a):
/// `α[2(N-1) + log N] + Mcβ[2(N-1)/N + log N]`
/// = broadcast of Mc index bytes + ring-AR of Mc value bytes.
pub fn art_ring(l: LinkParams, m: f64, n: usize, c: f64) -> f64 {
    let nf = n as f64;
    l.alpha * (2.0 * (nf - 1.0) + log2f(n))
        + m * c * l.beta * (2.0 * (nf - 1.0) / nf + log2f(n))
}

/// AR-Topk with tree reduction (Eqn 4b): `3α·log N + 3Mcβ·log N`.
pub fn art_tree(l: LinkParams, m: f64, n: usize, c: f64) -> f64 {
    3.0 * l.alpha * log2f(n) + 3.0 * m * c * l.beta * log2f(n)
}

/// The collectives the flexible strategy switches between.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CompressedCollective {
    AllgatherTopk,
    ArTopkRing,
    ArTopkTree,
}

impl CompressedCollective {
    pub fn name(&self) -> &'static str {
        match self {
            CompressedCollective::AllgatherTopk => "AG",
            CompressedCollective::ArTopkRing => "ART-Ring",
            CompressedCollective::ArTopkTree => "ART-Tree",
        }
    }

    pub fn cost(&self, l: LinkParams, m: f64, n: usize, c: f64) -> f64 {
        match self {
            CompressedCollective::AllgatherTopk => ag_topk(l, m, n, c),
            CompressedCollective::ArTopkRing => art_ring(l, m, n, c),
            CompressedCollective::ArTopkTree => art_tree(l, m, n, c),
        }
    }
}

/// Eqn 5a: use ART-Ring over ART-Tree iff
/// `α/β < Mc · (log N - (N-1)/N) / (N-1 - log N)`.
pub fn prefer_ring_over_tree(l: LinkParams, m: f64, n: usize, c: f64) -> bool {
    let nf = n as f64;
    let rhs = m * c * (log2f(n) - (nf - 1.0) / nf) / (nf - 1.0 - log2f(n));
    l.alpha / l.beta < rhs
}

/// Eqn 5b: use ART-Ring over AG iff
/// `α/β < Mc · (1 - 1/N - log N / (2(N-1)))`.
pub fn prefer_ring_over_ag(l: LinkParams, m: f64, n: usize, c: f64) -> bool {
    let nf = n as f64;
    let rhs = m * c * (1.0 - 1.0 / nf - log2f(n) / (2.0 * (nf - 1.0)));
    l.alpha / l.beta < rhs
}

/// Eqn 5c: use ART-Tree over AG iff
/// `α/β < Mc · ((N-1)/log N - 3/2)`.
pub fn prefer_tree_over_ag(l: LinkParams, m: f64, n: usize, c: f64) -> bool {
    let rhs = m * c * ((n as f64 - 1.0) / log2f(n) - 1.5);
    l.alpha / l.beta < rhs
}

/// Pick the cheapest of {AG, ART-Ring, ART-Tree} by direct cost evaluation.
/// (The Eqn 5 threshold form is algebraically equivalent — property-tested.)
pub fn optimal_collective(l: LinkParams, m: f64, n: usize, c: f64) -> CompressedCollective {
    use CompressedCollective::*;
    let mut best = AllgatherTopk;
    let mut best_cost = ag_topk(l, m, n, c);
    for cand in [ArTopkRing, ArTopkTree] {
        let cost = cand.cost(l, m, n, c);
        if cost < best_cost {
            best = cand;
            best_cost = cost;
        }
    }
    best
}

/// Pick ring vs tree for the dense (uncompressed) allreduce of DenseSGD.
pub fn optimal_dense_ar(l: LinkParams, m: f64, n: usize) -> &'static str {
    if ring_allreduce(l, m, n) <= tree_allreduce(l, m, n) {
        "Ring-AR"
    } else {
        "Tree-AR"
    }
}

// ---------------------------------------------------------------------------
// Heterogeneous-fleet costs (ISSUE 7): each entry point takes ONE link per
// worker (`links.len()` IS the cluster size) and prices every round of the
// collective's communication pattern by the slowest link participating in
// that round — a bulk-synchronous round finishes when its slowest pair does.
// When all links coincide, each function returns the homogeneous closed form
// above BITWISE (an explicit fast path, pinned by property tests), so the
// default `worker_link_at == link_at` world is untouched to the last ulp.
// ---------------------------------------------------------------------------

/// True when every per-worker link equals the first — the homogeneous
/// fast-path guard shared by the `hetero_*` entry points.
pub fn links_coincide(links: &[LinkParams]) -> bool {
    links.windows(2).all(|w| w[0] == w[1])
}

/// Componentwise-slowest link of a participant group: max α and max β —
/// the conservative single-link stand-in for a group that must all finish
/// (used for hierarchical node groups). Equals the common link when the
/// group is homogeneous.
pub fn slowest_link(links: &[LinkParams]) -> LinkParams {
    assert!(!links.is_empty(), "slowest_link of an empty group");
    links.iter().skip(1).fold(links[0], |acc, l| LinkParams {
        alpha: acc.alpha.max(l.alpha),
        beta: acc.beta.max(l.beta),
    })
}

/// One bulk-synchronous round moving `bytes` per participant: the round
/// completes when the slowest participant's transfer does.
fn round_cost(links: &[LinkParams], bytes: f64) -> f64 {
    links.iter().map(|l| l.alpha + bytes * l.beta).fold(0.0, f64::max)
}

/// Ring allreduce over per-worker links: all `2(N-1)` rounds involve every
/// worker (each sends a chunk to its neighbor simultaneously), so every
/// round is priced by the slowest worker moving `M/N` bytes. Reduces to
/// [`ring_allreduce`] exactly when the links coincide.
pub fn hetero_ring_allreduce(links: &[LinkParams], m: f64) -> f64 {
    let n = links.len();
    assert!(n >= 1, "ring over an empty fleet");
    if n == 1 || links_coincide(links) {
        return ring_allreduce(links[0], m, n);
    }
    2.0 * (n as f64 - 1.0) * round_cost(links, m / n as f64)
}

/// Recursive halving-doubling over per-worker links. The power-of-two core
/// (`links[..prev_pow2(n)]`) exchanges pairwise every round with bytes
/// halving per round, so each of the `2·log2(np)` rounds is priced by the
/// slowest core link at that round's byte count; the non-power-of-two fold
/// pairs each extra rank `np+i` with rank `i` moving the whole tensor, so
/// the two fold rounds are priced by the slowest link among exactly those
/// participants. Reduces to [`halving_doubling_allreduce`] exactly when
/// the links coincide.
pub fn hetero_halving_doubling_allreduce(links: &[LinkParams], m: f64) -> f64 {
    let n = links.len();
    assert!(n >= 1, "halving-doubling over an empty fleet");
    if n == 1 {
        return 0.0;
    }
    if links_coincide(links) {
        return halving_doubling_allreduce(links[0], m, n);
    }
    let np = prev_pow2(n);
    let extra = n - np;
    let mut cost = 0.0;
    if extra > 0 {
        let mut fold: Vec<LinkParams> = links[np..].to_vec();
        fold.extend_from_slice(&links[..extra]);
        cost += 2.0 * round_cost(&fold, m);
    }
    let core = &links[..np];
    let mut chunk = m;
    for _ in 0..np.trailing_zeros() {
        chunk /= 2.0;
        cost += 2.0 * round_cost(core, chunk);
    }
    cost
}

/// Two-level hierarchical allreduce over per-worker INTER links: the intra
/// phases ride the topology's (homogeneous, in-machine) `intra` link
/// unchanged, while each node's inter-facing cost is that of its
/// componentwise-slowest member ([`slowest_link`] — the leader cannot ship
/// a group's contribution faster than its slowest reachable member), and
/// the leader ring is priced per-round by [`hetero_ring_allreduce`].
/// `links.len()` must tile `t.workers_per_node` evenly. Reduces to
/// [`hierarchical_allreduce`] (with `inter = links[0]`) exactly when the
/// links coincide.
pub fn hetero_hierarchical_allreduce(t: Topology, links: &[LinkParams], m: f64) -> f64 {
    let n = links.len();
    assert!(n >= 1, "hierarchical over an empty fleet");
    let w = t.workers_per_node.max(1);
    if links_coincide(links) {
        let t2 = Topology { inter: links[0], ..t };
        return hierarchical_allreduce(t2, m, n);
    }
    let _ = t.nodes(n); // ragged fleets are rejected exactly like the closed form
    let leaders: Vec<LinkParams> = links.chunks(w).map(slowest_link).collect();
    2.0 * ceil_log2f(w) * (t.intra.alpha + m * t.intra.beta)
        + hetero_ring_allreduce(&leaders, m)
}

/// Allgather of a Top-k compressed tensor over per-worker links (ISSUE 8:
/// the compressed trio priced like the dense ops). Dissemination
/// (Bruck-style) allgather: round `i` ships the `min(2^i, N-2^i)` blocks
/// accumulated so far — each block the `2Mc` value+index bytes of one
/// worker's contribution — and the block counts sum to `N-1`, so every
/// byte of the homogeneous `2Mcβ(N-1)` term is priced by the slowest link
/// of its round. Reduces to [`ag_topk`] exactly when the links coincide.
pub fn hetero_ag_topk(links: &[LinkParams], m: f64, c: f64) -> f64 {
    let n = links.len();
    assert!(n >= 1, "allgather over an empty fleet");
    if n == 1 || links_coincide(links) {
        return ag_topk(links[0], m, n, c);
    }
    let block = 2.0 * m * c;
    let mut cost = 0.0;
    let mut sent = 1usize;
    while sent < n {
        cost += round_cost(links, sent.min(n - sent) as f64 * block);
        sent *= 2;
    }
    cost
}

/// AR-Topk ring (Eqn 4a) over per-worker links: a `log N`-round broadcast
/// of the `Mc` selected-index bytes plus a `2(N-1)`-round ring allreduce
/// of the `Mc` value bytes in `Mc/N` chunks, every round priced by its
/// slowest participant. Reduces to [`art_ring`] exactly when the links
/// coincide.
pub fn hetero_art_ring(links: &[LinkParams], m: f64, c: f64) -> f64 {
    let n = links.len();
    assert!(n >= 1, "AR-Topk ring over an empty fleet");
    if n == 1 || links_coincide(links) {
        return art_ring(links[0], m, n, c);
    }
    ceil_log2f(n) * round_cost(links, m * c)
        + 2.0 * (n as f64 - 1.0) * round_cost(links, m * c / n as f64)
}

/// AR-Topk tree (Eqn 4b) over per-worker links: three `log N`-round tree
/// traversals each moving the `Mc` compressed bytes, every round priced by
/// its slowest participant. Reduces to [`art_tree`] exactly when the
/// links coincide.
pub fn hetero_art_tree(links: &[LinkParams], m: f64, c: f64) -> f64 {
    let n = links.len();
    assert!(n >= 1, "AR-Topk tree over an empty fleet");
    if n == 1 || links_coincide(links) {
        return art_tree(links[0], m, n, c);
    }
    3.0 * ceil_log2f(n) * round_cost(links, m * c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    const MB100: f64 = 4e8; // 1e8 f32 params in bytes

    fn l(alpha_ms: f64, gbps: f64) -> LinkParams {
        LinkParams::from_ms_gbps(alpha_ms, gbps)
    }

    #[test]
    fn unit_conversions_roundtrip() {
        let p = l(4.0, 20.0);
        assert!((p.alpha_ms() - 4.0).abs() < 1e-12);
        assert!((p.bw_gbps() - 20.0).abs() < 1e-9);
        assert!((p.beta - 4e-10).abs() < 1e-22); // 8/(20e9)
    }

    /// Paper Table II spot checks: (α=10ms, 10Gbps), 1e8-param tensor.
    /// Ring-AR dense = 716 ms; our closed form should land near that
    /// (their number includes measurement noise; check ±15%).
    #[test]
    fn table2_ring_ar_magnitude() {
        let cost = ring_allreduce(l(10.0, 10.0), MB100, 8) * 1e3;
        // 2*7*10ms + 2*(7/8)*4e8*8e-10*1e3 = 140 + 560 = 700 ms
        assert!((cost - 700.0).abs() < 1.0, "got {cost}");
        // paper measured 716 ms -> within ~3%
        assert!((cost - 716.0).abs() / 716.0 < 0.15);
    }

    #[test]
    fn table2_ag_magnitude() {
        // AG CR 0.1 on 1e8 tensor @ (10ms, 10Gbps): paper (incl. compression)
        // reports 525 ms. Pure comm: 3*10 + 2*4e7*8e-10*7 = 478 ms.
        let cost = ag_topk(l(10.0, 10.0), MB100, 8, 0.1) * 1e3;
        assert!(cost > 400.0 && cost < 525.0, "got {cost}");
    }

    #[test]
    fn bandwidth_optimality_of_ring() {
        // Ring β-term ~ independent of N; AG grows with N.
        let p = l(0.0, 10.0);
        let r4 = ring_allreduce(p, MB100, 4);
        let r16 = ring_allreduce(p, MB100, 16);
        assert!(r16 / r4 < 1.3);
        let a4 = allgather(p, MB100, 4);
        let a16 = allgather(p, MB100, 16);
        assert!(a16 / a4 > 4.0);
    }

    #[test]
    fn latency_hurts_ring_more_than_tree() {
        let lo = l(1.0, 10.0);
        let hi = l(100.0, 10.0);
        let m = 4e6;
        let ring_penalty = ring_allreduce(hi, m, 8) - ring_allreduce(lo, m, 8);
        let tree_penalty = tree_allreduce(hi, m, 8) - tree_allreduce(lo, m, 8);
        assert!(ring_penalty > 2.0 * tree_penalty);
    }

    #[test]
    fn eqn5_thresholds_match_direct_costs() {
        check("eqn5 == argmin of closed-form costs", 500, |g| {
            let n = *g.choose(&[2usize, 4, 8, 16, 32]);
            let alpha_ms = g.f64_in(0.05, 200.0);
            let gbps = g.f64_in(0.2, 100.0);
            let m = g.f64_in(1e5, 5e9);
            let c = g.f64_in(1e-4, 0.5);
            let p = l(alpha_ms, gbps);
            ensure(
                prefer_ring_over_tree(p, m, n, c)
                    == (art_ring(p, m, n, c) < art_tree(p, m, n, c)),
                format!("5a mismatch n={n} α={alpha_ms} bw={gbps} m={m} c={c}"),
            )?;
            ensure(
                prefer_ring_over_ag(p, m, n, c)
                    == (art_ring(p, m, n, c) < ag_topk(p, m, n, c)),
                format!("5b mismatch n={n} α={alpha_ms} bw={gbps} m={m} c={c}"),
            )?;
            ensure(
                prefer_tree_over_ag(p, m, n, c)
                    == (art_tree(p, m, n, c) < ag_topk(p, m, n, c)),
                format!("5c mismatch n={n} α={alpha_ms} bw={gbps} m={m} c={c}"),
            )
        });
    }

    #[test]
    fn optimal_collective_is_argmin() {
        check("optimal_collective minimizes", 300, |g| {
            let n = *g.choose(&[2usize, 4, 8, 16]);
            let p = l(g.f64_in(0.1, 100.0), g.f64_in(0.5, 50.0));
            let m = g.f64_in(1e6, 4e9);
            let c = g.f64_in(1e-4, 0.3);
            let best = optimal_collective(p, m, n, c);
            let best_cost = best.cost(p, m, n, c);
            for cand in [
                CompressedCollective::AllgatherTopk,
                CompressedCollective::ArTopkRing,
                CompressedCollective::ArTopkTree,
            ] {
                ensure(
                    best_cost <= cand.cost(p, m, n, c) + 1e-15,
                    format!("{:?} beat chosen {:?}", cand, best),
                )?;
            }
            Ok(())
        });
    }

    /// Paper's qualitative regimes (§3-D): AG wins at tiny CR + decent
    /// bandwidth on a small model; ART-Ring wins on big models at low
    /// bandwidth; ART-Ring also wins at CR 0.1 and 10 Gbps.
    #[test]
    fn regime_shape_matches_paper() {
        let resnet18 = 4.0 * 11.7e6; // bytes
        let vit = 4.0 * 86.6e6;
        // Table VI row: ResNet18 (1ms,10G) CR 0.001 -> AG (3.28 vs 16.7/9).
        assert_eq!(
            optimal_collective(l(1.0, 10.0), resnet18, 8, 0.001).name(),
            "AG"
        );
        // Table VI: ResNet18 (1ms,10G) CR 0.1 -> ART-Ring (35 vs 54/43.2).
        assert_eq!(
            optimal_collective(l(1.0, 10.0), resnet18, 8, 0.1).name(),
            "ART-Ring"
        );
        // Table VI: ViT (1ms,1G) CR 0.01 -> ART-Ring (222.8 vs 601.8/385.2).
        assert_eq!(
            optimal_collective(l(1.0, 1.0), vit, 8, 0.01).name(),
            "ART-Ring"
        );
    }

    /// Fig 5: scale-out cost of AG grows much faster with N than ART-Ring.
    #[test]
    fn scaleout_slopes() {
        let p = l(5.0, 1.0);
        let m = 4.0 * 25.6e6;
        let c = 0.1;
        let ag_slope = ag_topk(p, m, 8, c) / ag_topk(p, m, 2, c);
        let art_slope = art_ring(p, m, 8, c) / art_ring(p, m, 2, c);
        assert!(ag_slope > 2.0 * art_slope, "ag {ag_slope} art {art_slope}");
    }

    #[test]
    fn costs_monotone_in_message_size() {
        check("costs monotone in m", 200, |g| {
            let n = *g.choose(&[2usize, 4, 8]);
            let p = l(g.f64_in(0.1, 50.0), g.f64_in(1.0, 40.0));
            let m1 = g.f64_in(1e5, 1e8);
            let m2 = m1 * g.f64_in(1.01, 10.0);
            for f in [
                ps_star,
                ring_allreduce,
                tree_allreduce,
                broadcast,
                allgather,
                halving_doubling_allreduce,
            ] {
                ensure(f(p, m2, n) >= f(p, m1, n), "dense op not monotone")?;
            }
            let c = g.f64_in(1e-3, 0.3);
            ensure(ag_topk(p, m2, n, c) >= ag_topk(p, m1, n, c), "ag")?;
            ensure(art_ring(p, m2, n, c) >= art_ring(p, m1, n, c), "ring")?;
            ensure(art_tree(p, m2, n, c) >= art_tree(p, m1, n, c), "tree")
        });
    }

    #[test]
    fn prev_pow2_values() {
        assert_eq!(prev_pow2(1), 1);
        assert_eq!(prev_pow2(2), 2);
        assert_eq!(prev_pow2(3), 2);
        assert_eq!(prev_pow2(6), 4);
        assert_eq!(prev_pow2(8), 8);
        assert_eq!(prev_pow2(9), 8);
    }

    /// HD-AR combines the ring's β-term with the tree's α-term, so for
    /// power-of-two N it can never lose to either in the α-β model.
    #[test]
    fn halving_doubling_dominates_ring_and_tree_pow2() {
        check("HD <= min(ring, tree) for pow2 N", 300, |g| {
            let n = *g.choose(&[2usize, 4, 8, 16, 32]);
            let p = l(g.f64_in(0.05, 200.0), g.f64_in(0.2, 100.0));
            let m = g.f64_in(1e5, 5e9);
            let hd = halving_doubling_allreduce(p, m, n);
            ensure(hd <= ring_allreduce(p, m, n) + 1e-12, "HD lost to ring")?;
            ensure(hd <= tree_allreduce(p, m, n) + 1e-12, "HD lost to tree")
        });
    }

    /// Non-power-of-two N pays the fold: two extra rounds moving M each.
    #[test]
    fn halving_doubling_non_pow2_penalty() {
        let p = l(5.0, 10.0);
        let m = 4e8;
        let pow2 = halving_doubling_allreduce(p, m, 4);
        let folded = halving_doubling_allreduce(p, m, 6);
        assert!(
            (folded - pow2 - 2.0 * (p.alpha + m * p.beta)).abs() < 1e-12,
            "fold penalty mismatch: {folded} vs {pow2}"
        );
        assert_eq!(halving_doubling_allreduce(p, m, 1), 0.0);
    }

    /// Hierarchical pays the slow inter link only nodes-wide: on a
    /// fast-intra/slow-inter topology it beats every flat dense collective.
    #[test]
    fn hierarchical_wins_on_asymmetric_topology() {
        let t = Topology::two_level(l(0.01, 100.0), l(10.0, 1.0), 4);
        let m = 4e8;
        let n = 8;
        let hier = hierarchical_allreduce(t, m, n);
        assert!(hier < ring_allreduce(t.inter, m, n), "vs flat ring");
        assert!(hier < tree_allreduce(t.inter, m, n), "vs flat tree");
        assert!(hier < halving_doubling_allreduce(t.inter, m, n), "vs flat HD");
    }

    /// Degenerate hierarchies collapse to known closed forms.
    #[test]
    fn hierarchical_degenerate_cases() {
        let fast = l(0.01, 100.0);
        let slow = l(10.0, 1.0);
        let m = 4e7;
        // w = 1: no intra phases — exactly the flat ring on the inter link.
        let flat = Topology::two_level(fast, slow, 1);
        assert!((hierarchical_allreduce(flat, m, 8) - ring_allreduce(slow, m, 8)).abs() < 1e-12);
        // Single node: no inter phase — exactly the intra tree allreduce.
        let one_node = Topology::two_level(fast, slow, 8);
        assert!(
            (hierarchical_allreduce(one_node, m, 8) - tree_allreduce(fast, m, 8)).abs() < 1e-12
        );
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn topology_rejects_ragged_nodes() {
        Topology::two_level(l(0.01, 100.0), l(10.0, 1.0), 3).nodes(8);
    }

    #[test]
    fn topology_scale_beta_scales_both_links() {
        let t = Topology::two_level(l(0.01, 100.0), l(4.0, 20.0), 4).scale_beta(10.0);
        assert!((t.intra.beta - 10.0 * 8.0 / 100e9).abs() < 1e-21);
        assert!((t.inter.beta - 10.0 * 4e-10).abs() < 1e-21);
        assert!((t.intra.alpha - 1e-5).abs() < 1e-15, "alpha unchanged");
        assert_eq!(t.workers_per_node, 4);
    }

    /// Every dense collective's closed-form cost is monotone
    /// (non-decreasing) in the message bytes under ANY link parameters —
    /// the sanity floor every α-β formula must clear: more bytes can never
    /// communicate faster. Also covers the hierarchical form under random
    /// two-level topologies.
    #[test]
    fn dense_costs_are_monotone_in_message_bytes() {
        type DenseCost = (&'static str, fn(LinkParams, f64, usize) -> f64);
        const FORMS: &[DenseCost] = &[
            ("ps_star", ps_star),
            ("ring_allreduce", ring_allreduce),
            ("tree_allreduce", tree_allreduce),
            ("halving_doubling_allreduce", halving_doubling_allreduce),
            ("broadcast", broadcast),
            ("allgather", allgather),
        ];
        check("dense cost monotone in bytes", 400, |g| {
            let link = l(g.f64_in(0.0, 100.0), g.f64_in(0.01, 100.0));
            let n = g.usize_in(2, 64);
            let m1 = g.f64_in(0.0, 1e9);
            let m2 = m1 + g.f64_in(0.0, 1e9);
            for (name, f) in FORMS {
                let (c1, c2) = (f(link, m1, n), f(link, m2, n));
                ensure(
                    c1.is_finite() && c2.is_finite() && c1 <= c2 + 1e-12 * c2.abs(),
                    format!("{name}: cost({m1}) = {c1} > cost({m2}) = {c2} at n={n}, {link:?}"),
                )?;
            }
            // Hierarchical: random two-level topology tiling n evenly.
            let wpn = *g.choose(&[1usize, 2, 4, 8]);
            let n = wpn * g.usize_in(1, 8).max(if wpn == 1 { 2 } else { 1 });
            let t = Topology::two_level(l(g.f64_in(0.0, 1.0), g.f64_in(1.0, 200.0)), link, wpn);
            let (h1, h2) = (hierarchical_allreduce(t, m1, n), hierarchical_allreduce(t, m2, n));
            ensure(
                h1.is_finite() && h2.is_finite() && h1 <= h2 + 1e-12 * h2.abs(),
                format!("hierarchical: cost({m1}) = {h1} > cost({m2}) = {h2} at n={n}, wpn={wpn}"),
            )
        });
    }

    /// ISSUE 7 pin, exact-reduction half: with identical per-worker links
    /// every heterogeneous entry point returns the homogeneous closed form
    /// BITWISE — the fast path is the closed form, so the default
    /// `worker_link_at == link_at` world cannot drift by even an ulp.
    #[test]
    fn hetero_costs_reduce_bitwise_to_homogeneous_closed_forms() {
        check("hetero == homogeneous when links coincide", 400, |g| {
            let p = l(g.f64_in(0.01, 100.0), g.f64_in(0.1, 100.0));
            let m = g.f64_in(1e4, 1e9);
            let n = g.usize_in(1, 64);
            let links = vec![p; n];
            ensure(
                hetero_ring_allreduce(&links, m).to_bits()
                    == ring_allreduce(p, m, n).to_bits(),
                format!("ring n={n}"),
            )?;
            ensure(
                hetero_halving_doubling_allreduce(&links, m).to_bits()
                    == halving_doubling_allreduce(p, m, n).to_bits(),
                format!("hd n={n}"),
            )?;
            // The compressed trio (ISSUE 8): same exact-reduction contract.
            let c = g.f64_in(1e-3, 1.0);
            ensure(
                hetero_ag_topk(&links, m, c).to_bits() == ag_topk(p, m, n, c).to_bits(),
                format!("ag-topk n={n}"),
            )?;
            ensure(
                hetero_art_ring(&links, m, c).to_bits() == art_ring(p, m, n, c).to_bits(),
                format!("art-ring n={n}"),
            )?;
            ensure(
                hetero_art_tree(&links, m, c).to_bits() == art_tree(p, m, n, c).to_bits(),
                format!("art-tree n={n}"),
            )?;
            let wpn = *g.choose(&[1usize, 2, 4]);
            let nh = wpn * g.usize_in(1, 16);
            let t = Topology::two_level(l(g.f64_in(0.0, 1.0), g.f64_in(1.0, 200.0)), p, wpn);
            ensure(
                hetero_hierarchical_allreduce(t, &vec![p; nh], m).to_bits()
                    == hierarchical_allreduce(t, m, nh).to_bits(),
                format!("hier n={nh} wpn={wpn}"),
            )
        });
    }

    /// ISSUE 7 pin, monotonicity half: degrading any SINGLE worker's link
    /// (α and/or bandwidth by a factor >= 1) can never make any
    /// heterogeneous collective cheaper — a slower participant can only
    /// stretch the rounds it takes part in.
    #[test]
    fn hetero_costs_monotone_in_any_single_link_degradation() {
        check("hetero cost monotone under one-link degrade", 400, |g| {
            let m = g.f64_in(1e4, 1e9);
            let nodes = g.usize_in(1, 16);
            let wpn = *g.choose(&[1usize, 2, 4]);
            let n = (nodes * wpn).max(2);
            let mut links: Vec<LinkParams> =
                (0..n).map(|_| l(g.f64_in(0.01, 50.0), g.f64_in(0.5, 50.0))).collect();
            let c = g.f64_in(1e-3, 1.0);
            let before_ring = hetero_ring_allreduce(&links, m);
            let before_hd = hetero_halving_doubling_allreduce(&links, m);
            let before_ag = hetero_ag_topk(&links, m, c);
            let before_art_ring = hetero_art_ring(&links, m, c);
            let before_art_tree = hetero_art_tree(&links, m, c);
            let t = Topology::two_level(l(0.01, 100.0), links[0], wpn);
            let before_hier = if n % wpn == 0 {
                Some(hetero_hierarchical_allreduce(t, &links, m))
            } else {
                None
            };
            let i = g.usize_in(0, n - 1);
            let fa = g.f64_in(1.0, 16.0);
            let fb = g.f64_in(1.0, 16.0);
            links[i].alpha *= fa;
            links[i].beta *= fb;
            let tol = 1e-12;
            ensure(
                hetero_ring_allreduce(&links, m) >= before_ring * (1.0 - tol),
                format!("ring regressed after degrading link {i} of {n}"),
            )?;
            ensure(
                hetero_halving_doubling_allreduce(&links, m) >= before_hd * (1.0 - tol),
                format!("hd regressed after degrading link {i} of {n}"),
            )?;
            ensure(
                hetero_ag_topk(&links, m, c) >= before_ag * (1.0 - tol),
                format!("ag-topk regressed after degrading link {i} of {n}"),
            )?;
            ensure(
                hetero_art_ring(&links, m, c) >= before_art_ring * (1.0 - tol),
                format!("art-ring regressed after degrading link {i} of {n}"),
            )?;
            ensure(
                hetero_art_tree(&links, m, c) >= before_art_tree * (1.0 - tol),
                format!("art-tree regressed after degrading link {i} of {n}"),
            )?;
            if let Some(b) = before_hier {
                ensure(
                    hetero_hierarchical_allreduce(t, &links, m) >= b * (1.0 - tol),
                    format!("hier regressed after degrading link {i} of {n} (wpn={wpn})"),
                )?;
            }
            Ok(())
        });
    }

    /// The compressed trio's hetero structure: one slow worker stretches
    /// every round it participates in, and the AG dissemination rounds'
    /// block counts account for exactly the homogeneous `2Mcβ(N-1)` bytes.
    #[test]
    fn hetero_compressed_trio_waits_for_the_slowest_worker() {
        let fast = l(1.0, 25.0);
        let slow = l(8.0, 3.0);
        let m = 4e8;
        let c = 0.01;
        let mut links = vec![fast; 8];
        links[5] = slow;
        // Every round of each pattern is priced by the slow link: AG's
        // dissemination rounds ship 1, 2, 4 blocks of 2Mc bytes (= 7
        // contributions, N-1); ART-Ring broadcasts Mc over log2(8) rounds
        // then rings Mc in Mc/8 chunks; ART-Tree walks 3 log2(8) rounds
        // of Mc.
        let per = |bytes: f64| slow.alpha + bytes * slow.beta;
        let want_ag = per(2.0 * m * c) + per(2.0 * 2.0 * m * c) + per(4.0 * 2.0 * m * c);
        assert!((hetero_ag_topk(&links, m, c) - want_ag).abs() < 1e-12);
        let want_ring = 3.0 * per(m * c) + 14.0 * per(m * c / 8.0);
        assert!((hetero_art_ring(&links, m, c) - want_ring).abs() < 1e-12);
        let want_tree = 9.0 * per(m * c);
        assert!((hetero_art_tree(&links, m, c) - want_tree).abs() < 1e-12);
        // And each strictly exceeds its all-fast fleet.
        assert!(hetero_ag_topk(&links, m, c) > hetero_ag_topk(&vec![fast; 8], m, c));
        assert!(hetero_art_ring(&links, m, c) > hetero_art_ring(&vec![fast; 8], m, c));
        assert!(hetero_art_tree(&links, m, c) > hetero_art_tree(&vec![fast; 8], m, c));
    }

    /// A single slow worker dominates the ring: every round waits for it.
    #[test]
    fn hetero_ring_waits_for_the_slowest_worker() {
        let fast = l(1.0, 25.0);
        let slow = l(8.0, 3.0);
        let mut links = vec![fast; 8];
        links[5] = slow;
        let m = 4e8;
        let got = hetero_ring_allreduce(&links, m);
        let want = 2.0 * 7.0 * (slow.alpha + (m / 8.0) * slow.beta);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        // And it exceeds the all-fast fleet strictly.
        assert!(got > hetero_ring_allreduce(&vec![fast; 8], m));
    }

    /// The hetero HD fold rounds only pay for the folded participants:
    /// degrading a CORE-only link must not change the fold cost share,
    /// while degrading an extra rank's link must.
    #[test]
    fn hetero_hd_fold_prices_only_its_participants() {
        let fast = l(1.0, 25.0);
        let slow = l(20.0, 1.0);
        let m = 4e8;
        // n = 6: core = ranks 0..4, extras = ranks 4..6 folding into 0..2.
        let mut core_slow = vec![fast; 6];
        core_slow[3] = slow; // core-only rank (not a fold participant)
        let mut extra_slow = vec![fast; 6];
        extra_slow[4] = slow; // fold participant
        let base = hetero_halving_doubling_allreduce(&vec![fast; 6], m);
        let with_core = hetero_halving_doubling_allreduce(&core_slow, m);
        let with_extra = hetero_halving_doubling_allreduce(&extra_slow, m);
        // Core-rank degrade stretches only the 2·log2(4) core rounds.
        let core_round_delta = with_core - base;
        let core_expect: f64 = [m / 2.0, m / 4.0]
            .iter()
            .map(|b| 2.0 * ((slow.alpha + b * slow.beta) - (fast.alpha + b * fast.beta)))
            .sum();
        assert!((core_round_delta - core_expect).abs() < 1e-9, "{core_round_delta}");
        // Extra-rank degrade stretches only the two fold rounds.
        let fold_delta = with_extra - base;
        let fold_expect = 2.0 * ((slow.alpha + m * slow.beta) - (fast.alpha + m * fast.beta));
        assert!((fold_delta - fold_expect).abs() < 1e-9, "{fold_delta}");
    }

    /// Hierarchical groups: a slow member slows ITS node's inter ring slot
    /// via the componentwise-slowest leader link.
    #[test]
    fn hetero_hierarchical_groups_by_slowest_member() {
        let fast = l(1.0, 25.0);
        let slow = l(10.0, 2.0);
        let intra = l(0.01, 100.0);
        let t = Topology::two_level(intra, fast, 4);
        let m = 4e8;
        let mut links = vec![fast; 8];
        links[6] = slow; // second node carries the slow member
        let got = hetero_hierarchical_allreduce(t, &links, m);
        let leaders = [fast, slowest_link(&[fast, fast, slow, fast])];
        let want = 2.0 * 2.0 * (intra.alpha + m * intra.beta)
            + hetero_ring_allreduce(&leaders, m);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        assert_eq!(slowest_link(&[fast, slow]), LinkParams {
            alpha: slow.alpha.max(fast.alpha),
            beta: slow.beta.max(fast.beta),
        });
        assert!(links_coincide(&[fast, fast]) && !links_coincide(&links));
    }
}
