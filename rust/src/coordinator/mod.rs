//! L3 coordinator: the synchronous data-parallel training loop, collective
//! selection (Eqn 5), and the MOO-adaptive compression controller (§3-E).

pub mod adaptive;
pub mod checkpoint;
pub mod metrics;
pub mod policy_switch;
pub mod selector;
pub mod trainer;
pub mod worker;

pub use adaptive::AdaptiveConfig;
pub use metrics::{MetricsLog, StepMetrics};
pub use trainer::{Strategy, TrainConfig, Trainer};
pub use worker::{ComputeModel, GradSource};
