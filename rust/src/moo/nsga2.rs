//! NSGA-II (Deb et al. 2002) — the solver the paper runs (via pymoo) to
//! find `c_optimal`. Generic over [`Problem`]; decision variables live in
//! [0, 1]^d and are mapped by the problem itself.

use crate::moo::pareto::dominates;
use crate::util::rng::Rng;

/// A multi-objective problem: evaluate genes in [0,1]^n_var to a vector of
/// minimized objectives.
pub trait Problem {
    fn n_var(&self) -> usize;
    fn n_obj(&self) -> usize;
    fn evaluate(&self, x: &[f64]) -> Vec<f64>;
}

#[derive(Debug, Clone)]
pub struct Nsga2Config {
    pub pop_size: usize,
    pub generations: usize,
    /// SBX crossover distribution index (paper-standard 15).
    pub eta_crossover: f64,
    /// Polynomial mutation distribution index (paper-standard 20).
    pub eta_mutation: f64,
    pub crossover_prob: f64,
    pub mutation_prob: f64,
    pub seed: u64,
}

impl Default for Nsga2Config {
    fn default() -> Self {
        Nsga2Config {
            pop_size: 40,
            generations: 60,
            eta_crossover: 15.0,
            eta_mutation: 20.0,
            crossover_prob: 0.9,
            mutation_prob: 0.2,
            seed: 0,
        }
    }
}

/// One evaluated individual.
#[derive(Debug, Clone)]
pub struct Individual {
    pub genes: Vec<f64>,
    pub objectives: Vec<f64>,
    rank: usize,
    crowding: f64,
}

/// Final population (rank-0 slice = approximated Pareto set).
#[derive(Debug)]
pub struct Nsga2Result {
    pub population: Vec<Individual>,
}

impl Nsga2Result {
    /// The non-dominated front of the final population.
    pub fn front(&self) -> Vec<&Individual> {
        self.population.iter().filter(|i| i.rank == 0).collect()
    }
}

/// Fast non-dominated sort: assigns ranks; returns fronts as index lists.
fn non_dominated_sort(objs: &[Vec<f64>]) -> Vec<Vec<usize>> {
    let n = objs.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut dom_count = vec![0usize; n];
    for i in 0..n {
        for j in 0..n {
            if i != j {
                if dominates(&objs[i], &objs[j]) {
                    dominated_by[i].push(j);
                } else if dominates(&objs[j], &objs[i]) {
                    dom_count[i] += 1;
                }
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| dom_count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::replace(&mut current, next));
    }
    fronts
}

/// Crowding distance within one front.
fn crowding_distances(objs: &[Vec<f64>], front: &[usize]) -> Vec<f64> {
    let mut dist = vec![0.0f64; front.len()];
    if front.len() <= 2 {
        return vec![f64::INFINITY; front.len()];
    }
    let n_obj = objs[front[0]].len();
    for d in 0..n_obj {
        let mut order: Vec<usize> = (0..front.len()).collect();
        order.sort_by(|&a, &b| {
            crate::tensor::nan_min_cmp(objs[front[a]][d], objs[front[b]][d])
        });
        let lo = objs[front[order[0]]][d];
        let hi = objs[front[*order.last().unwrap()]][d];
        dist[order[0]] = f64::INFINITY;
        dist[*order.last().unwrap()] = f64::INFINITY;
        if hi > lo {
            for w in 1..order.len() - 1 {
                let prev = objs[front[order[w - 1]]][d];
                let next = objs[front[order[w + 1]]][d];
                dist[order[w]] += (next - prev) / (hi - lo);
            }
        }
    }
    dist
}

/// SBX crossover on one gene pair.
fn sbx(a: f64, b: f64, eta: f64, rng: &mut Rng) -> (f64, f64) {
    let u = rng.f64();
    let beta = if u <= 0.5 {
        (2.0 * u).powf(1.0 / (eta + 1.0))
    } else {
        (1.0 / (2.0 * (1.0 - u))).powf(1.0 / (eta + 1.0))
    };
    let c1 = 0.5 * ((1.0 + beta) * a + (1.0 - beta) * b);
    let c2 = 0.5 * ((1.0 - beta) * a + (1.0 + beta) * b);
    (c1.clamp(0.0, 1.0), c2.clamp(0.0, 1.0))
}

/// Polynomial mutation on one gene.
fn poly_mutate(x: f64, eta: f64, rng: &mut Rng) -> f64 {
    let u = rng.f64();
    let delta = if u < 0.5 {
        (2.0 * u).powf(1.0 / (eta + 1.0)) - 1.0
    } else {
        1.0 - (2.0 * (1.0 - u)).powf(1.0 / (eta + 1.0))
    };
    (x + delta).clamp(0.0, 1.0)
}

/// Binary tournament by (rank, crowding).
fn tournament<'a>(pop: &'a [Individual], rng: &mut Rng) -> &'a Individual {
    let a = &pop[rng.below(pop.len())];
    let b = &pop[rng.below(pop.len())];
    if a.rank < b.rank || (a.rank == b.rank && a.crowding > b.crowding) {
        a
    } else {
        b
    }
}

/// Run NSGA-II on `problem`.
pub fn optimize<P: Problem>(problem: &P, cfg: &Nsga2Config) -> Nsga2Result {
    assert!(cfg.pop_size >= 4 && cfg.pop_size % 2 == 0);
    let mut rng = Rng::new(cfg.seed);
    let nv = problem.n_var();

    let eval = |genes: Vec<f64>, problem: &P| -> Individual {
        let objectives = problem.evaluate(&genes);
        debug_assert_eq!(objectives.len(), problem.n_obj());
        Individual { genes, objectives, rank: usize::MAX, crowding: 0.0 }
    };

    // Init.
    let mut pop: Vec<Individual> = (0..cfg.pop_size)
        .map(|_| eval((0..nv).map(|_| rng.f64()).collect(), problem))
        .collect();
    assign_rank_crowding(&mut pop);

    for _gen in 0..cfg.generations {
        // Offspring.
        let mut offspring = Vec::with_capacity(cfg.pop_size);
        while offspring.len() < cfg.pop_size {
            let p1 = tournament(&pop, &mut rng).genes.clone();
            let p2 = tournament(&pop, &mut rng).genes.clone();
            let (mut c1, mut c2) = (p1.clone(), p2.clone());
            if rng.f64() < cfg.crossover_prob {
                for i in 0..nv {
                    let (a, b) = sbx(p1[i], p2[i], cfg.eta_crossover, &mut rng);
                    c1[i] = a;
                    c2[i] = b;
                }
            }
            for c in [&mut c1, &mut c2] {
                for gene in c.iter_mut() {
                    if rng.f64() < cfg.mutation_prob {
                        *gene = poly_mutate(*gene, cfg.eta_mutation, &mut rng);
                    }
                }
            }
            offspring.push(eval(c1, problem));
            if offspring.len() < cfg.pop_size {
                offspring.push(eval(c2, problem));
            }
        }

        // Environmental selection on parents + offspring.
        pop.extend(offspring);
        let objs: Vec<Vec<f64>> = pop.iter().map(|i| i.objectives.clone()).collect();
        let fronts = non_dominated_sort(&objs);
        let mut next: Vec<Individual> = Vec::with_capacity(cfg.pop_size);
        for front in fronts {
            if next.len() == cfg.pop_size {
                break;
            }
            let dists = crowding_distances(&objs, &front);
            let mut members: Vec<(usize, f64)> =
                front.iter().copied().zip(dists).collect();
            if next.len() + members.len() > cfg.pop_size {
                members.sort_by(|a, b| crate::tensor::nan_min_cmp(b.1, a.1));
                members.truncate(cfg.pop_size - next.len());
            }
            for (idx, crowd) in members {
                let mut ind = pop[idx].clone();
                ind.crowding = crowd;
                next.push(ind);
            }
        }
        pop = next;
        assign_rank_crowding(&mut pop);
    }

    Nsga2Result { population: pop }
}

fn assign_rank_crowding(pop: &mut [Individual]) {
    let objs: Vec<Vec<f64>> = pop.iter().map(|i| i.objectives.clone()).collect();
    let fronts = non_dominated_sort(&objs);
    for (rank, front) in fronts.iter().enumerate() {
        let dists = crowding_distances(&objs, front);
        for (&i, &d) in front.iter().zip(&dists) {
            pop[i].rank = rank;
            pop[i].crowding = d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ZDT1-style 1-var toy: objectives (x, (1-x)^2) — the true front is the
    /// whole [0,1] segment; check spread + optimality.
    struct Toy;

    impl Problem for Toy {
        fn n_var(&self) -> usize {
            1
        }
        fn n_obj(&self) -> usize {
            2
        }
        fn evaluate(&self, x: &[f64]) -> Vec<f64> {
            vec![x[0], (1.0 - x[0]) * (1.0 - x[0])]
        }
    }

    /// A problem with a known single optimum dominating everything:
    /// f = ((x-0.3)^2, (x-0.3)^2 + 1).
    struct SingleOpt;

    impl Problem for SingleOpt {
        fn n_var(&self) -> usize {
            1
        }
        fn n_obj(&self) -> usize {
            2
        }
        fn evaluate(&self, x: &[f64]) -> Vec<f64> {
            let d = (x[0] - 0.3) * (x[0] - 0.3);
            vec![d, d + 1.0]
        }
    }

    #[test]
    fn finds_single_optimum() {
        let res = optimize(&SingleOpt, &Nsga2Config { seed: 1, ..Default::default() });
        let front = res.front();
        assert!(!front.is_empty());
        for ind in front {
            assert!((ind.genes[0] - 0.3).abs() < 0.05, "gene {}", ind.genes[0]);
        }
    }

    #[test]
    fn front_spreads_on_tradeoff() {
        let res = optimize(&Toy, &Nsga2Config { seed: 2, ..Default::default() });
        let front = res.front();
        assert!(front.len() >= 10);
        let xs: Vec<f64> = front.iter().map(|i| i.genes[0]).collect();
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(hi - lo > 0.5, "front collapsed: [{lo}, {hi}]");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = optimize(&Toy, &Nsga2Config { seed: 7, generations: 10, ..Default::default() });
        let b = optimize(&Toy, &Nsga2Config { seed: 7, generations: 10, ..Default::default() });
        let ga: Vec<f64> = a.population.iter().map(|i| i.genes[0]).collect();
        let gb: Vec<f64> = b.population.iter().map(|i| i.genes[0]).collect();
        assert_eq!(ga, gb);
    }

    #[test]
    fn sort_ranks_are_consistent() {
        let objs = vec![
            vec![0.0, 0.0], // rank 0
            vec![1.0, 1.0], // rank 1
            vec![2.0, 2.0], // rank 2
            vec![0.5, 0.1], // incomparable with [0,0]? 0.5>0, 0.1>0 -> dominated; rank 1
        ];
        let fronts = non_dominated_sort(&objs);
        assert_eq!(fronts[0], vec![0]);
        assert!(fronts[1].contains(&1) || fronts[1].contains(&3));
    }

    #[test]
    fn genes_stay_in_bounds() {
        let res = optimize(&Toy, &Nsga2Config { seed: 3, generations: 30, ..Default::default() });
        for ind in &res.population {
            assert!((0.0..=1.0).contains(&ind.genes[0]));
        }
    }

    /// A problem that injects NaN objectives for part of the gene range —
    /// the crowding/selection sorts must neither panic nor go
    /// non-deterministic now that they use the crate NaN total order.
    struct NanPoisoned;

    impl Problem for NanPoisoned {
        fn n_var(&self) -> usize {
            1
        }
        fn n_obj(&self) -> usize {
            2
        }
        fn evaluate(&self, x: &[f64]) -> Vec<f64> {
            if x[0] > 0.7 {
                vec![f64::NAN, x[0]]
            } else {
                vec![x[0], (1.0 - x[0]) * (1.0 - x[0])]
            }
        }
    }

    #[test]
    fn nan_objectives_do_not_panic_and_stay_deterministic() {
        let cfg = Nsga2Config { seed: 11, generations: 12, ..Default::default() };
        let a = optimize(&NanPoisoned, &cfg);
        let b = optimize(&NanPoisoned, &cfg);
        let ga: Vec<u64> = a.population.iter().map(|i| i.genes[0].to_bits()).collect();
        let gb: Vec<u64> = b.population.iter().map(|i| i.genes[0].to_bits()).collect();
        assert_eq!(ga, gb, "NaN-poisoned run must stay bitwise-deterministic");
    }

    /// The comparator swap (`partial_cmp().unwrap()` -> `nan_min_cmp`) must
    /// be behavior-preserving on non-NaN inputs: pin the crowding sort and
    /// descending selection order bitwise against a reference ordering.
    #[test]
    fn non_nan_ordering_pinned_bitwise_unchanged() {
        let objs = vec![
            vec![0.3, 2.0],
            vec![0.1, 3.0],
            vec![0.7, 1.0],
            vec![0.5, 1.5],
            vec![0.2, 2.5],
        ];
        let front: Vec<usize> = (0..objs.len()).collect();
        let dists = crowding_distances(&objs, &front);
        // Reference: the exact same crowding computation with the old
        // comparator (total on these finite inputs).
        let mut ref_dist = vec![0.0f64; front.len()];
        for d in 0..2 {
            let mut order: Vec<usize> = (0..front.len()).collect();
            // flexlint::allow(nan-partial-cmp): reference comparator the pin test compares against
            order.sort_by(|&a, &b| objs[a][d].partial_cmp(&objs[b][d]).unwrap());
            let lo = objs[order[0]][d];
            let hi = objs[*order.last().unwrap()][d];
            ref_dist[order[0]] = f64::INFINITY;
            ref_dist[*order.last().unwrap()] = f64::INFINITY;
            if hi > lo {
                for w in 1..order.len() - 1 {
                    ref_dist[order[w]] += (objs[order[w + 1]][d] - objs[order[w - 1]][d]) / (hi - lo);
                }
            }
        }
        let got: Vec<u64> = dists.iter().map(|d| d.to_bits()).collect();
        let want: Vec<u64> = ref_dist.iter().map(|d| d.to_bits()).collect();
        assert_eq!(got, want, "crowding distances changed on non-NaN inputs");

        // Descending selection sort order identical to the old comparator.
        let mut members: Vec<(usize, f64)> = front.iter().copied().zip(dists.clone()).collect();
        members.sort_by(|a, b| crate::tensor::nan_min_cmp(b.1, a.1));
        let mut reference: Vec<(usize, f64)> = front.iter().copied().zip(dists).collect();
        // flexlint::allow(nan-partial-cmp): reference comparator the pin test compares against
        reference.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let got: Vec<usize> = members.iter().map(|m| m.0).collect();
        let want: Vec<usize> = reference.iter().map(|m| m.0).collect();
        assert_eq!(got, want, "selection order changed on non-NaN inputs");
    }
}
