"""L1 perf-structure checks (EXPERIMENTS.md §Perf): VMEM budgets, MXU
utilization estimates, and the one-pass fusion of ef_compress — the
structural properties we optimize for TPU (interpret-mode wallclock is not
a TPU proxy; structure is)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.kernels import ef_compress as efc
from compile.kernels import matmul as mm
from compile.kernels import topk_threshold as tkt

jax.config.update("jax_platform_name", "cpu")


def test_matmul_vmem_budget_under_double_buffering():
    # 3 f32 tiles of 128^2 = 192 KiB; 2x for double buffering still < 1 MiB,
    # i.e. ~6% of a 16 MiB VMEM — the budget DESIGN.md §7 records.
    assert mm.vmem_bytes() == 3 * 128 * 128 * 4
    assert 2 * mm.vmem_bytes() < 1 << 20


def test_mxu_utilization_of_shipped_presets():
    # Every transformer preset's hot matmuls (b*t x d) @ (d x 4d): estimate
    # utilization and require the big presets to be exactly MXU-aligned.
    for name, cfg in M.TRANSFORMER_PRESETS.items():
        rows = cfg.batch * cfg.seq
        u = mm.mxu_utilization_estimate(rows, cfg.mlp_hidden, cfg.dim)
        assert 0.0 < u <= 1.0
        if name in ("base", "large"):
            assert u == 1.0, f"{name}: dims must be multiples of 128, got {u}"


def test_ef_compress_vmem_budget():
    # 4 streams x 4096 f32 = 64 KiB per grid step.
    assert efc.vmem_bytes() == 4 * 4096 * 4 + 12
    assert efc.vmem_bytes() < 1 << 17


def _count(text: str, needle: str) -> int:
    return text.count(needle)


def test_ef_compress_is_single_fused_pass():
    """The fused kernel must lower to ONE pallas region over the tensor —
    the 4-pass naive chain would show four. We count the kernel-body marker
    in the jaxpr (each pallas_call appears once per lowered call site)."""
    g = jax.ShapeDtypeStruct((8192,), jnp.float32)
    tau = jax.ShapeDtypeStruct((), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda a, b, t: efc.ef_compress(a, b, t))(g, g, tau)
    n_pallas = _count(str(jaxpr), "pallas_call")
    assert n_pallas == 1, f"expected 1 fused pallas_call, found {n_pallas}"


def test_threshold_estimation_pass_count_matches_rounds():
    """estimate_threshold runs exactly `rounds` counting passes (plus one
    absmax) — the Fig 2 cost profile. The count kernel sits inside a
    fori_loop, so the jaxpr shows absmax + the loop-body count call."""
    g = jax.ShapeDtypeStruct((8192,), jnp.float32)
    k = jax.ShapeDtypeStruct((), jnp.float32)
    jaxpr = str(jax.make_jaxpr(lambda a, kk: tkt.estimate_threshold(a, kk, rounds=25))(g, k))
    # One absmax pallas_call + one count pallas_call inside the while body.
    assert _count(jaxpr, "pallas_call") == 2, jaxpr.count("pallas_call")
    assert "while" in jaxpr or "scan" in jaxpr


def test_fused_ef_matches_two_pass_composition():
    """Numerics of the fused one-pass kernel == mask + manual residual
    (the pre-fusion implementation) — the optimization changed pass count,
    not results."""
    rng = np.random.default_rng(0)
    g = rng.standard_normal(5000).astype(np.float32)
    r = (rng.standard_normal(5000) * 0.2).astype(np.float32)
    tau = 0.8
    gc1, res1, nc1, ne1 = efc.ef_compress(jnp.array(g), jnp.array(r), tau, block=1024)
    g_e = g + r
    gc2 = np.asarray(tkt.mask(jnp.array(g_e), tau, block=1024))
    res2 = g_e - gc2
    np.testing.assert_allclose(np.asarray(gc1), gc2, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(res1), res2, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(nc1), float(np.sum(gc2**2)), rtol=1e-4)
    np.testing.assert_allclose(float(ne1), float(np.sum(g_e**2)), rtol=1e-4)


def test_grad_artifact_single_forward_trace():
    """value_and_grad must not re-trace the forward inside the backward:
    the tiny preset's jaxpr contains each Pallas matmul call site a bounded
    number of times (fwd + the two VJP matmuls), not doubled by remat."""
    cfg = M.TRANSFORMER_PRESETS["tiny"]
    p = M.param_count(M.transformer_layout(cfg))
    f = M.grad_fn("transformer", cfg)
    jaxpr = str(
        jax.make_jaxpr(f)(
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32),
        )
    )
    n = _count(jaxpr, "pallas_call")
    # 2 MLP matmuls/layer x 2 layers = 4 fwd sites, each with dx+dw in the
    # bwd = 12 total. Anything >> that indicates recomputation.
    assert n <= 14, f"pallas_call sites {n} — forward likely recomputed"
