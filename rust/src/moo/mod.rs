//! Multi-objective optimization of the compression ratio (paper §3-E).
//!
//! The paper models CR selection as a 3-objective problem — minimize
//! compression time, minimize communication time, maximize compression
//! gain (minimize 1/gain) — solved with NSGA-II (they use pymoo; here the
//! algorithm is first-party and property-tested).
//!
//! * [`nsga2`] — generic NSGA-II: fast non-dominated sort, crowding
//!   distance, binary tournament, SBX crossover, polynomial mutation.
//! * [`pareto`] — dominance tests, front extraction, knee-point selection.
//! * [`problem`] — the CR problem built from measured candidate profiles.

pub mod nsga2;
pub mod pareto;
pub mod problem;

pub use nsga2::{Nsga2Config, Problem};
pub use pareto::{dominates, knee_point, pareto_front};
pub use problem::{CandidateProfile, CrProblem};
