//! PJRT runtime (L3 ⇄ L2 boundary): load the AOT-lowered HLO artifacts and
//! execute them from the training hot path, plus host-side gradient sources
//! for simulator-only experiments.
//!
//! The PJRT path needs the vendored `xla` bindings (and their native
//! `xla_extension` libraries), so it sits behind the `pjrt` cargo feature.
//! Default builds swap in API-identical stubs that fail at *runtime* with a
//! clear message — every simulator-only workload (host models, cost tables,
//! schedules) works without the feature.

pub mod artifact;
pub mod host_model;

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod pjrt_model;

#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_model_stub.rs"]
pub mod pjrt_model;

pub use artifact::{find_artifacts_dir, ModelArtifacts};
pub use engine::Engine;
pub use host_model::{HostMlp, SyntheticGrad};
pub use pjrt_model::PjrtModel;
