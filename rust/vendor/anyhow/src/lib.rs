//! First-party offline shim of the `anyhow` error-handling API.
//!
//! The flexcomm build philosophy (see the crate docs of `flexcomm` and
//! DESIGN.md §5) is to vendor everything: this crate provides the subset of
//! anyhow the repo actually uses — [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait —
//! with no dependencies, so `cargo build` works with no registry access.
//!
//! Fidelity notes: errors carry a flattened message chain (`context: cause`)
//! rather than anyhow's source-preserving chain, and there is no downcast
//! support. Both are deliberate: flexcomm only ever formats its errors.

use std::fmt;

/// A flattened error: the message already includes any context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> Result<()>` prints errors via Debug; show the
        // message, not a struct dump.
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>`: a [`Result`](std::result::Result) defaulting to
/// [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a message, a format string, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
}

/// Attach context to a failure: `res.context("reading config")?`.
pub trait Context<T> {
    /// Prefix the error with `ctx` (evaluated eagerly).
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Prefix the error with `f()` (evaluated only on failure).
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {}", e.msg) })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {}", f(), e.msg) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error { msg: ctx.to_string() })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        std::fs::read("/definitely/not/a/path/žž")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fails_io().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 7;
        let e = anyhow!("x = {x}");
        assert_eq!(e.to_string(), "x = 7");
        let e = anyhow!("x = {}", x);
        assert_eq!(e.to_string(), "x = 7");
        let e = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");
    }

    #[test]
    fn bail_and_ensure_return_early() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: boom");

        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");

        let n: Option<u8> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn debug_is_message() {
        assert_eq!(format!("{:?}", anyhow!("msg")), "msg");
    }
}
