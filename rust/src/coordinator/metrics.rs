//! Per-step metrics: the timing breakdown of Eqn 3 plus everything the
//! paper's tables/figures are built from (loss, collective used, CR,
//! broadcasting rank, gain).

use crate::collectives::CollectiveKind;
use crate::util::stats;

/// One training step's record.
#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: u64,
    pub epoch: f64,
    pub loss: f64,
    /// Simulated forward+backward seconds (max over workers).
    pub t_compute: f64,
    /// MEASURED compression (+decompression) seconds on the coordinator.
    pub t_comp: f64,
    /// Simulated communication seconds.
    pub t_sync: f64,
    pub collective: CollectiveKind,
    pub cr: f64,
    /// Rank that broadcast its indices (AR-Topk only).
    pub selected_rank: Option<usize>,
    pub gain: f64,
    /// Probed link at this step (ms, Gbps).
    pub alpha_ms: f64,
    pub bw_gbps: f64,
}

impl StepMetrics {
    /// CSV column header shared by [`MetricsLog::to_csv`] and the
    /// streaming `CsvSink` observer (no trailing newline).
    pub const CSV_HEADER: &'static str =
        "step,epoch,loss,t_compute,t_comp,t_sync,t_step,collective,cr,selected_rank,gain,alpha_ms,bw_gbps";

    /// Total step time (Eqn 3, `t_IO` folded into compute).
    pub fn t_step(&self) -> f64 {
        self.t_compute + self.t_comp + self.t_sync
    }

    /// One CSV row matching [`StepMetrics::CSV_HEADER`] (no newline).
    pub fn csv_row(&self) -> String {
        format!(
            "{},{:.4},{:.6},{:.6},{:.6},{:.6},{:.6},{},{:.6},{},{:.4},{:.3},{:.3}",
            self.step,
            self.epoch,
            self.loss,
            self.t_compute,
            self.t_comp,
            self.t_sync,
            self.t_step(),
            self.collective.name(),
            self.cr,
            self.selected_rank.map(|r| r.to_string()).unwrap_or_default(),
            self.gain,
            self.alpha_ms,
            self.bw_gbps,
        )
    }
}

/// Append-only metrics log with summary/CSV export.
#[derive(Debug, Default)]
pub struct MetricsLog {
    pub steps: Vec<StepMetrics>,
    /// (epoch, eval loss, eval accuracy) records.
    pub evals: Vec<(f64, f64, f64)>,
}

/// Aggregate view over a step range.
#[derive(Debug, Clone)]
pub struct Summary {
    pub steps: usize,
    pub mean_step_s: f64,
    pub mean_compute_s: f64,
    pub mean_comp_s: f64,
    pub mean_sync_s: f64,
    pub mean_gain: f64,
    pub final_loss: f64,
}

impl MetricsLog {
    pub fn record(&mut self, m: StepMetrics) {
        self.steps.push(m);
    }

    pub fn record_eval(&mut self, epoch: f64, loss: f64, acc: f64) {
        self.evals.push((epoch, loss, acc));
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.evals.last().map(|&(_, _, a)| a)
    }

    /// Best (max) eval accuracy — the "Acc." column of Tables III-V.
    pub fn best_accuracy(&self) -> Option<f64> {
        self.evals.iter().map(|&(_, _, a)| a).fold(None, |m, a| {
            Some(m.map_or(a, |b: f64| b.max(a)))
        })
    }

    pub fn summary(&self) -> Summary {
        self.summary_range(0, self.steps.len())
    }

    pub fn summary_range(&self, from: usize, to: usize) -> Summary {
        let s = &self.steps[from..to];
        let col = |f: fn(&StepMetrics) -> f64| -> Vec<f64> { s.iter().map(f).collect() };
        Summary {
            steps: s.len(),
            mean_step_s: stats::mean(&col(StepMetrics::t_step)),
            mean_compute_s: stats::mean(&col(|m| m.t_compute)),
            mean_comp_s: stats::mean(&col(|m| m.t_comp)),
            mean_sync_s: stats::mean(&col(|m| m.t_sync)),
            mean_gain: stats::mean(&col(|m| m.gain)),
            final_loss: s.last().map(|m| m.loss).unwrap_or(f64::NAN),
        }
    }

    /// Density inputs for the paper's KDE figures.
    pub fn selected_ranks(&self) -> Vec<f64> {
        self.steps
            .iter()
            .filter_map(|m| m.selected_rank.map(|r| r as f64))
            .collect()
    }

    pub fn crs_used(&self) -> Vec<f64> {
        self.steps.iter().map(|m| m.cr).collect()
    }

    pub fn collectives_used(&self) -> Vec<CollectiveKind> {
        self.steps.iter().map(|m| m.collective).collect()
    }

    /// Per-collective usage counts, ordered by first appearance — the raw
    /// data behind the Fig 8 densities and the per-topology crossover
    /// tables (which collective the selector settled on, and for how long).
    pub fn collective_counts(&self) -> Vec<(CollectiveKind, usize)> {
        let mut out: Vec<(CollectiveKind, usize)> = Vec::new();
        for m in &self.steps {
            match out.iter_mut().find(|e| e.0 == m.collective) {
                Some(e) => e.1 += 1,
                None => out.push((m.collective, 1)),
            }
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(StepMetrics::CSV_HEADER);
        out.push('\n');
        for m in &self.steps {
            out.push_str(&m.csv_row());
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(step: u64, sync: f64) -> StepMetrics {
        StepMetrics {
            step,
            epoch: step as f64 / 10.0,
            loss: 1.0 / (step as f64 + 1.0),
            t_compute: 0.01,
            t_comp: 0.002,
            t_sync: sync,
            collective: CollectiveKind::ArTopkRing,
            cr: 0.01,
            selected_rank: Some((step % 4) as usize),
            gain: 0.8,
            alpha_ms: 4.0,
            bw_gbps: 20.0,
        }
    }

    #[test]
    fn t_step_is_eqn3() {
        assert!((m(0, 0.05).t_step() - 0.062).abs() < 1e-12);
    }

    #[test]
    fn summary_means() {
        let mut log = MetricsLog::default();
        log.record(m(0, 0.05));
        log.record(m(1, 0.15));
        let s = log.summary();
        assert_eq!(s.steps, 2);
        assert!((s.mean_sync_s - 0.10).abs() < 1e-12);
        assert!((s.mean_step_s - 0.112).abs() < 1e-12);
        assert!((s.final_loss - 0.5).abs() < 1e-12);
    }

    #[test]
    fn accuracy_tracking() {
        let mut log = MetricsLog::default();
        assert!(log.final_accuracy().is_none());
        log.record_eval(1.0, 0.5, 0.7);
        log.record_eval(2.0, 0.4, 0.9);
        log.record_eval(3.0, 0.45, 0.85);
        assert_eq!(log.final_accuracy(), Some(0.85));
        assert_eq!(log.best_accuracy(), Some(0.9));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = MetricsLog::default();
        log.record(m(0, 0.1));
        let csv = log.to_csv();
        assert!(csv.starts_with("step,epoch,loss"));
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("ART-Ring"));
    }

    #[test]
    fn density_extracts() {
        let mut log = MetricsLog::default();
        for i in 0..8 {
            log.record(m(i, 0.1));
        }
        assert_eq!(log.selected_ranks().len(), 8);
        assert_eq!(log.crs_used()[0], 0.01);
        assert_eq!(log.collectives_used()[0], CollectiveKind::ArTopkRing);
    }

    #[test]
    fn collective_counts_order_and_totals() {
        let mut log = MetricsLog::default();
        for i in 0..6 {
            let mut s = m(i, 0.1);
            s.collective = if i % 3 == 0 {
                CollectiveKind::HierarchicalAllreduce
            } else {
                CollectiveKind::HalvingDoublingAllreduce
            };
            log.record(s);
        }
        assert_eq!(
            log.collective_counts(),
            vec![
                (CollectiveKind::HierarchicalAllreduce, 2),
                (CollectiveKind::HalvingDoublingAllreduce, 4),
            ]
        );
    }
}
