//! Random-k baseline (§2-C1): keep k uniformly random coordinates.
//!
//! With a SHARED seed sequence all workers draw the same indices each step,
//! which makes Random-k natively allreduce-compatible — the paper cites it
//! as the AR-friendly compressor with poor convergence; the ablation bench
//! uses it as the lower bound on statistical efficiency.

use crate::compress::{k_for, Compressor, SparseGrad};
use crate::tensor::Layout;
use crate::util::rng::Rng;

/// Random-k compressor. Workers constructed with the same seed draw
/// identical index sets on every call (call-count keyed). Carries a
/// per-instance index scratch so `compress_into` is allocation-free in
/// steady state.
#[derive(Debug, Clone)]
pub struct RandomK {
    seed: u64,
    calls: u64,
    idx_scratch: Vec<usize>,
}

impl RandomK {
    pub fn new(seed: u64) -> Self {
        RandomK { seed, calls: 0, idx_scratch: Vec::new() }
    }

    /// The index set for a given step (pure function of seed + step).
    pub fn indices_for_step(&self, step: u64, len: usize, k: usize) -> Vec<usize> {
        let mut out = Vec::new();
        Self::indices_for_step_into(self.seed, step, len, k, &mut out);
        out
    }

    fn indices_for_step_into(seed: u64, step: u64, len: usize, k: usize, out: &mut Vec<usize>) {
        let mut rng = Rng::new(seed ^ step.wrapping_mul(0xA076_1D64_78BD_642F));
        rng.sample_indices_into(len, k, out);
    }
}

impl Compressor for RandomK {
    fn name(&self) -> &'static str {
        "randomk"
    }

    fn compress(&mut self, g: &[f32], cr: f64, layout: &Layout) -> SparseGrad {
        let mut out = SparseGrad::default();
        self.compress_into(g, cr, layout, &mut out);
        out
    }

    fn compress_into(&mut self, g: &[f32], cr: f64, _layout: &Layout, out: &mut SparseGrad) {
        let k = k_for(cr, g.len());
        Self::indices_for_step_into(self.seed, self.calls, g.len(), k, &mut self.idx_scratch);
        self.calls += 1;
        out.indices.clear();
        out.indices.extend(self.idx_scratch.iter().map(|&i| i as u32));
        out.values.clear();
        out.values.extend(self.idx_scratch.iter().map(|&i| g[i]));
        out.dense_len = g.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    #[test]
    fn same_seed_same_indices_across_workers() {
        let layout = Layout::single(100);
        let mut a = RandomK::new(9);
        let mut b = RandomK::new(9);
        let ga = crate::util::rng::Rng::new(1).fork(0);
        let _ = ga;
        let g1: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let g2: Vec<f32> = (0..100).map(|i| -(i as f32)).collect();
        for _ in 0..5 {
            let sa = a.compress(&g1, 0.1, &layout);
            let sb = b.compress(&g2, 0.1, &layout);
            assert_eq!(sa.indices, sb.indices, "AR-compatibility requires shared indices");
        }
    }

    #[test]
    fn different_steps_differ() {
        let layout = Layout::single(1000);
        let mut c = RandomK::new(3);
        let g = vec![1.0f32; 1000];
        let s1 = c.compress(&g, 0.05, &layout);
        let s2 = c.compress(&g, 0.05, &layout);
        assert_ne!(s1.indices, s2.indices);
    }

    #[test]
    fn k_and_validity() {
        check("randomk validity", 60, |gen| {
            let n = gen.usize_in(1, 400);
            let g = gen.vec_normal(n, 1.0);
            let cr = gen.f64_in(0.01, 1.0);
            let mut c = RandomK::new(gen.rng.next_u64());
            let s = c.compress(&g, cr, &Layout::single(n));
            ensure(s.k() == k_for(cr, n), "wrong k")?;
            for (&i, &v) in s.indices.iter().zip(&s.values) {
                ensure(v == g[i as usize], "value mismatch")?;
            }
            let mut sorted = s.indices.clone();
            sorted.dedup();
            ensure(sorted.len() == s.k(), "duplicates")
        });
    }
}
