//! Minimal CLI argument parser (offline build: no `clap`).
//!
//! Grammar: `flexcomm <subcommand> [--key value]... [--flag]... [positional]...`
//! Flags may also be written `--key=value`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand if it
    /// doesn't start with `-`).
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` ends option parsing.
                    out.positional.extend(it);
                    break;
                }
                if let Some(eq) = body.find('=') {
                    out.options
                        .insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let val = it.next().unwrap();
                    out.options.insert(body.to_string(), val);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Result<Args> {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => match s.parse() {
                Ok(v) => Ok(v),
                Err(_) => bail!("--{name}: expected integer, got `{s}`"),
            },
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => match s.parse() {
                Ok(v) => Ok(v),
                Err(_) => bail!("--{name}: expected integer, got `{s}`"),
            },
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => match s.parse() {
                Ok(v) => Ok(v),
                Err(_) => bail!("--{name}: expected number, got `{s}`"),
            },
        }
    }

    /// Comma-separated f64 list option.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.opt(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{name}: bad number `{p}`"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        // NB grammar: `--opt` followed by a non-dash token consumes it as a
        // value, so positionals go before options or after `--`.
        let a = parse(&["train", "pos1", "--workers", "8", "--cr=0.01", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("workers", 1).unwrap(), 8);
        assert_eq!(a.f64_or("cr", 0.1).unwrap(), 0.01);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["cost", "--table1"]);
        assert!(a.flag("table1"));
        assert_eq!(a.opt("table1"), None);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_or("n", 3).is_err());
        assert_eq!(a.usize_or("m", 3).unwrap(), 3);
        assert_eq!(a.str_or("s", "d"), "d");
    }

    #[test]
    fn f64_list() {
        let a = parse(&["x", "--crs", "0.1,0.01,0.001"]);
        assert_eq!(a.f64_list_or("crs", &[]).unwrap(), vec![0.1, 0.01, 0.001]);
        assert_eq!(a.f64_list_or("other", &[1.0]).unwrap(), vec![1.0]);
    }

    #[test]
    fn double_dash_positional() {
        let a = parse(&["run", "--", "--not-an-option"]);
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }
}
