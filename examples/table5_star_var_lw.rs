//! Table V: STAR-Topk vs VAR-Topk (Allreduce) vs LWTopk (Allgather)
//! head-to-head — step time AND accuracy per (model, CR).
//!
//!     cargo run --release --example table5_star_var_lw -- [--steps 600]
//!         [--models ResNet18,ViT|all]

use anyhow::Result;
use flexcomm::artopk::{ArFlavor, SelectionPolicy};
use flexcomm::compress::CompressorKind;
use flexcomm::coordinator::trainer::{CrControl, Strategy};
use flexcomm::experiments::{
    proxy_cfg, run_proxy, GPU_COMPRESS_SPEEDUP, PAPER_COMPUTE_MS, PAPER_MODELS,
};
use flexcomm::util::cli::Args;
use flexcomm::util::table::Table;

const PROXY_PARAMS: f64 = 53_664.0;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let steps = args.u64_or("steps", 600)?;
    let want = args.str_or("models", "ResNet18,ViT");
    let crs = [0.1, 0.01, 0.001];

    println!("== Table V — STAR vs VAR (Allreduce) vs LW (Allgather), 4ms/20Gbps ==");
    let mut tab = Table::new([
        "Model", "CR", "STAR t(ms)", "VAR t(ms)", "LW t(ms)", "STAR acc", "VAR acc", "LW acc",
    ]);
    for (model, params) in PAPER_MODELS {
        if want != "all" && !want.split(',').any(|m| m == model) {
            continue;
        }
        let msg_scale = params / PROXY_PARAMS;
        let compute_ms = PAPER_COMPUTE_MS.iter().find(|(m, _)| *m == model).unwrap().1;
        for &cr in &crs {
            let mk = |strategy| {
                let mut cfg = proxy_cfg(strategy, CrControl::Static(cr), steps, 1);
                cfg.msg_scale = msg_scale;
                cfg.comp_scale = msg_scale / GPU_COMPRESS_SPEEDUP;
                cfg.compute = flexcomm::coordinator::worker::ComputeModel::with_jitter(
                    compute_ms * 1e-3,
                    0.05,
                );
                run_proxy(cfg, 1)
            };
            let star = mk(Strategy::ArTopkFixed {
                policy: SelectionPolicy::Star,
                flavor: ArFlavor::Ring,
            });
            let var = mk(Strategy::ArTopkFixed {
                policy: SelectionPolicy::Var,
                flavor: ArFlavor::Ring,
            });
            let lw = mk(Strategy::AgCompress { kind: CompressorKind::LwTopk });
            let ms = |r: &flexcomm::coordinator::session::TrainReport| {
                format!("{:.2}", r.summary().mean_step_s * 1e3)
            };
            let acc = |r: &flexcomm::coordinator::session::TrainReport| {
                format!("{:.2}", r.best_accuracy().unwrap_or(f64::NAN) * 100.0)
            };
            tab.row([
                model.to_string(),
                format!("{cr}"),
                ms(&star),
                ms(&var),
                ms(&lw),
                acc(&star),
                acc(&var),
                acc(&lw),
            ]);
        }
    }
    tab.print();
    println!(
        "\nShape checks (paper §3-C3): VAR t_step > STAR t_step (extra variance AG); \
         at CR 0.1 fused AR-Topk accuracy matches or beats layerwise LW. At lower \
         CRs the one-worker-per-step information bottleneck is amplified at proxy \
         scale — see EXPERIMENTS.md Table IV deviations."
    );
    Ok(())
}
