"""AOT pipeline: lowered HLO text is well-formed and numerically faithful.

Executes the lowered artifact text through jax's own HLO client path is not
available here, so we check (a) the text parses structurally, (b) the
lowered computation's entry signature matches the manifest, and (c) the
jitted python graph and the ref agree — the rust integration test
(rust/tests/) closes the loop by executing the same text via PJRT.
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model as M
from compile.kernels import ef_compress as efc, topk_threshold as tkt

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x, y: (jnp.matmul(x, y) + 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True -> root is a tuple
    assert "tuple" in text


def test_export_preset_writes_all_files():
    with tempfile.TemporaryDirectory() as d:
        aot.export_preset(d, "mlp", force=True)
        cfg = M.MLP_PRESETS["mlp"]
        p = M.param_count(M.mlp_layout(cfg))
        for f in [
            "mlp_grad.hlo.txt",
            "mlp_eval.hlo.txt",
            "mlp_step.hlo.txt",
            "mlp_layout.txt",
            "mlp_meta.txt",
            f"ef_topk_{p}.hlo.txt",
            "mlp_init.f32",
        ]:
            path = os.path.join(d, f)
            assert os.path.exists(path), f
            assert os.path.getsize(path) > 0, f


def test_layout_file_matches_param_count():
    with tempfile.TemporaryDirectory() as d:
        aot.export_preset(d, "mlp", force=True)
        rows = [
            line.split()
            for line in open(os.path.join(d, "mlp_layout.txt"))
            if line.strip()
        ]
        total = int(rows[-1][1]) + int(rows[-1][2])
        meta = dict(
            line.strip().split("=", 1)
            for line in open(os.path.join(d, "mlp_meta.txt"))
        )
        assert total == int(meta["param_count"])
        init = np.fromfile(os.path.join(d, "mlp_init.f32"), dtype="<f4")
        assert init.size == total


def test_ef_topk_graph_semantics():
    """The exact graph exported as ef_topk_<P> keeps ~k and conserves mass."""
    p = 8192
    rng = np.random.default_rng(0)
    g = rng.standard_normal(p).astype(np.float32)
    r = (rng.standard_normal(p) * 0.2).astype(np.float32)

    def f(g, residual, k):
        g_e = g + residual
        tau = tkt.estimate_threshold(g_e, k, rounds=25)
        return efc.ef_compress(g, residual, tau) + (tau,)

    k = 200.0
    gc, res, nc, ne, tau = jax.jit(f)(jnp.array(g), jnp.array(r), k)
    kept = int(np.sum(np.asarray(gc) != 0))
    assert abs(kept - k) <= max(2, int(0.02 * k) + 1)
    np.testing.assert_allclose(
        np.asarray(gc) + np.asarray(res), g + r, rtol=1e-6, atol=1e-7
    )
    assert 0.0 < float(nc) / float(ne) <= 1.0


def test_skip_existing_is_noop(capsys):
    with tempfile.TemporaryDirectory() as d:
        aot.export_preset(d, "mlp", force=True)
        stamp = {
            f: os.path.getmtime(os.path.join(d, f)) for f in os.listdir(d)
        }
        aot.export_preset(d, "mlp", force=False)
        for f, t in stamp.items():
            assert os.path.getmtime(os.path.join(d, f)) == t, f
