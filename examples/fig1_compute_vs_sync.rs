//! Fig 1: (a) compute vs synchronization time per model on 8 workers;
//! (b) intra-node vs inter-node synchronization latency.
//!
//!     cargo run --release --example fig1_compute_vs_sync
//!
//! Substitution: intra-node NVLink/PCIe is modeled as a 5µs/300Gbps link,
//! inter-node as 100µs/10Gbps (the paper's data-center setting).

use anyhow::Result;
use flexcomm::experiments::{PAPER_COMPUTE_MS, PAPER_MODELS};
use flexcomm::netsim::cost_model::{self, LinkParams};
use flexcomm::util::table::Table;

fn main() -> Result<()> {
    let n = 8;
    let intra = LinkParams::from_ms_gbps(0.005, 300.0);
    let inter = LinkParams::from_ms_gbps(0.1, 10.0);

    println!("== Fig 1a — compute vs sync per step (8 workers, Ring-AR) ==");
    let mut t = Table::new([
        "Model", "params (M)", "compute (ms)", "sync intra (ms)", "sync inter (ms)", "comm-bound?",
    ]);
    for ((model, params), (_, compute_ms)) in PAPER_MODELS.iter().zip(PAPER_COMPUTE_MS.iter()) {
        let m = 4.0 * params;
        let si = cost_model::ring_allreduce(intra, m, n) * 1e3;
        let se = cost_model::ring_allreduce(inter, m, n) * 1e3;
        t.row([
            model.to_string(),
            format!("{:.1}", params / 1e6),
            format!("{compute_ms:.0}"),
            format!("{si:.2}"),
            format!("{se:.1}"),
            if se > *compute_ms { "yes".into() } else { "no".to_string() },
        ]);
    }
    t.print();

    println!("\n== Fig 1b — aggregation latency: 8 GPUs/node vs 1 GPU/node ==");
    let mut t = Table::new(["Model", "intra-node (ms)", "inter-node 10Gbps (ms)", "ratio"]);
    for (model, params) in PAPER_MODELS {
        let m = 4.0 * params;
        let a = cost_model::ring_allreduce(intra, m, n) * 1e3;
        let b = cost_model::ring_allreduce(inter, m, n) * 1e3;
        t.row([
            model.to_string(),
            format!("{a:.2}"),
            format!("{b:.1}"),
            format!("{:.0}x", b / a),
        ]);
    }
    t.print();
    println!(
        "\nShape check (paper): inter-node sync dominates compute for every model; \
         communication is the bottleneck that motivates compression."
    );
    Ok(())
}
