//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Every stochastic component in the crate (data generation, compressor
//! sampling, NSGA-II, network jitter) takes an explicit [`Rng`] so whole
//! experiments replay bit-identically from a seed.

/// xoshiro256** generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box-Muller.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. one per worker) from this one.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // flexlint::allow(release-silent-assert): release still panics loudly — `% n` divides by zero on the same call
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Normal with given mean/std as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with i.i.d. N(0, std^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.sample_indices_into(n, k, &mut out);
        out
    }

    /// [`Rng::sample_indices`] into a caller-owned scratch buffer (cleared
    /// first) — zero allocations once `out` has grown to capacity `k`.
    ///
    /// Membership is tracked in the sorted output itself via binary search
    /// instead of a hash set: the `below` draw sequence and the accept /
    /// replace-with-`j` decisions are identical to the hash-set
    /// formulation (a pinned test proves it), so callers see the exact
    /// same sorted index set — this is a pure allocation change.
    pub fn sample_indices_into(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        assert!(k <= n);
        out.clear();
        out.reserve(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            match out.binary_search(&t) {
                // `t` already chosen: Floyd inserts `j` instead — and `j`
                // is strictly larger than every element so far, so it
                // appends (keeping `out` sorted).
                Ok(_) => out.push(j),
                Err(pos) => out.insert(pos, t),
            }
        }
    }

    /// Draw from a categorical distribution given (unnormalised) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(1);
        let mut acc = 0.0;
        for _ in 0..20_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        assert!((acc / 20_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(4);
        let idx = r.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        assert!(idx.iter().all(|&i| i < 100));
    }

    /// The binary-search formulation must reproduce the original hash-set
    /// Floyd sampler EXACTLY (same draws, same output) — Random-k's
    /// shared-index AR-compatibility depends on this sequence never
    /// changing. The closure below is the pre-arena implementation,
    /// verbatim.
    #[test]
    fn sample_indices_into_matches_hashset_floyd() {
        let old_floyd = |rng: &mut Rng, n: usize, k: usize| -> Vec<usize> {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            for j in (n - k)..n {
                let t = rng.below(j + 1);
                if !chosen.insert(t) {
                    chosen.insert(j);
                }
            }
            let mut v: Vec<usize> = chosen.into_iter().collect();
            v.sort_unstable();
            v
        };
        let mut scratch = Vec::new();
        for seed in 0..50u64 {
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            let n = 1 + (seed as usize * 37) % 500;
            let k = (seed as usize * 13) % (n + 1);
            let want = old_floyd(&mut a, n, k);
            b.sample_indices_into(n, k, &mut scratch);
            assert_eq!(scratch, want, "seed={seed} n={n} k={k}");
            // And the generators are in the same state afterwards.
            assert_eq!(a.next_u64(), b.next_u64(), "draw count differs");
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let p2 = counts[2] as f64 / 30_000.0;
        assert!((p2 - 0.7).abs() < 0.03);
    }
}
