//! Perf-pass micro-benches for the L3 hot paths (EXPERIMENTS.md §Perf):
//! Top-k selection (heap vs quickselect), MSTopk threshold rounds, ring
//! allreduce arithmetic, sparse allgather scatter, EF bookkeeping, and the
//! threaded worker engine (grad+compress stage, threads=1 vs N — the
//! ISSUE 2 acceptance bench; also run in smoke mode by scripts/verify.sh,
//! which hard-fails if the parallel stage is not bitwise-identical to the
//! serial one), and the kernel layer (scalar reference vs chunked
//! `tensor::kernels` primitive, pinned bitwise, per-primitive speedups).
//!
//!     cargo bench --bench hotpath
//!     FLEXCOMM_BENCH_FAST=1 cargo bench --bench hotpath   (CI smoke mode)

use flexcomm::artopk::{ArFlavor, ArTopk, SelectionPolicy};
use flexcomm::collectives::ring_allreduce;
use flexcomm::compress::topk::{topk_indices, topk_indices_select};
use flexcomm::compress::{Compressor, EfState, MsTopk, SparseGrad, TopK};
use flexcomm::netsim::cost_model::LinkParams;
use flexcomm::tensor::{kernels, nan_min_cmp_f32, Layout};
use flexcomm::util::bench::Bencher;
use std::cmp::Ordering;
use flexcomm::util::pool::ThreadPool;
use flexcomm::util::rng::Rng;

// The bench-local scalar references below hardcode 8 lanes / an 8-way
// combine; keep them in sync with the kernel layer's chunk width.
const _: () = assert!(kernels::LANES == 8);

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn pair_bits(v: &[(f32, u32)]) -> Vec<(u32, u32)> {
    v.iter().map(|&(m, i)| (m.to_bits(), i)).collect()
}

/// The lane-split sq-norm DEFINITION (element `i` -> lane `i % 8`, fixed
/// pairwise combine) as a plain strided loop: the pinned crate reduction
/// policy the chunked kernel must match bitwise. NOT the retired
/// sequential fold — that produced different low bits and is gone.
fn ref_sq_norm_strided(x: &[f32]) -> f64 {
    let mut acc = [0.0f64; 8];
    for (i, &v) in x.iter().enumerate() {
        let v = v as f64;
        acc[i % 8] += v * v;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Strided-definition dot product, same policy as [`ref_sq_norm_strided`].
fn ref_dot_strided(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = [0.0f64; 8];
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        acc[i % 8] += x as f64 * y as f64;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Reference implementation of the PRE-persistent-pool execution engine:
/// spawn a fresh scoped thread per worker per region, exactly the chunking
/// the persistent pool uses (`workers = threads.min(n)`, contiguous ceil
/// chunks, results by item index). Kept here, bench-local, so the
/// spawn-vs-park stage measures the real historical alternative and the
/// bitwise assert pins the persistent pool to the same outputs.
fn scoped_map<R, F>(threads: usize, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let workers = threads.min(n).max(1);
    let chunk = (n + workers - 1) / workers;
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        for (w, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, slot) in slice.iter_mut().enumerate() {
                    *slot = Some(f(w * chunk + j));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("every slot filled")).collect()
}

fn main() {
    let fast = std::env::var("FLEXCOMM_BENCH_FAST").is_ok();
    let dim: usize = if fast { 200_000 } else { 4_000_000 };
    let mut rng = Rng::new(0);
    let mut g = vec![0.0f32; dim];
    rng.fill_normal(&mut g, 1.0);
    let k = dim / 100;
    let mut b = Bencher::from_env();

    // Top-k selection: the paper's max-heap vs quickselect.
    b.bench(&format!("topk heap        G={dim} k={k}"), || {
        Bencher::black_box(topk_indices(&g, k));
    });
    b.bench(&format!("topk quickselect G={dim} k={k}"), || {
        Bencher::black_box(topk_indices_select(&g, k));
    });

    // MSTopk threshold rounds.
    for rounds in [10u32, 25] {
        let mut ms = MsTopk::new(rounds);
        b.bench(&format!("mstopk rounds={rounds} G={dim}"), || {
            Bencher::black_box(ms.compress(&g, 0.01, &Layout::single(dim)));
        });
    }

    // Ring allreduce arithmetic (data path, 8 workers).
    let n = 8;
    let bufs: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut v = vec![0.0; dim / 4];
            Rng::new(i as u64).fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let link = LinkParams::from_ms_gbps(1.0, 10.0);
    b.bench(&format!("ring_allreduce data n={n} m={}", dim / 4), || {
        let mut bb = bufs.clone();
        Bencher::black_box(ring_allreduce(&mut bb, link));
    });

    // Full AR-Topk exchange (compress + residuals + reduce).
    let grads: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut v = vec![0.0; dim / 4];
            Rng::new(100 + i as u64).fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let mut art = ArTopk::new(SelectionPolicy::Star, ArFlavor::Ring);
    b.bench(&format!("artopk exchange n={n} G={} cr=0.01", dim / 4), || {
        let mut ef: Vec<EfState> = (0..n).map(|_| EfState::new(dim / 4)).collect();
        Bencher::black_box(art.exchange(&grads, &mut ef, 0.01, 0, link));
    });

    // EF bookkeeping alone.
    let mut ef = EfState::new(dim);
    let sparse = flexcomm::compress::SparseGrad {
        indices: (0..k as u32).collect(),
        values: vec![1.0; k],
        dense_len: dim,
    };
    b.bench(&format!("error-feedback update G={dim}"), || {
        let ge = ef.error_fed(&g);
        ef.update(Bencher::black_box(ge), &sparse);
    });

    // ------------------------------------------------------------------
    // Threaded worker engine: the grad+compress stage of a 4-worker step
    // (per worker: O(G) gradient transform + error-feed + top-k select),
    // threads=1 vs all cores. ISSUE 2 acceptance: >=1.5x on a >=4-core
    // host. The outputs must be bitwise identical — that part is a hard
    // check, valid on any core count.
    // ------------------------------------------------------------------
    let nw = 4;
    let wdim = dim / 4;
    let wk = wdim / 100;
    let base: Vec<Vec<f32>> = (0..nw)
        .map(|i| {
            let mut v = vec![0.0; wdim];
            Rng::new(1000 + i as u64).fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let residual = vec![0.01f32; wdim];
    let stage = |pool: &ThreadPool| -> Vec<Vec<u32>> {
        pool.map(nw, |w| {
            // "grad": a deterministic O(G) per-worker transform standing in
            // for backprop, then the AG-path compress (EF + selection).
            let g_w: Vec<f32> = base[w].iter().map(|&v| v * 1.000123 + 0.1).collect();
            let g_e: Vec<f32> = g_w.iter().zip(&residual).map(|(a, r)| a + r).collect();
            topk_indices_select(&g_e, wk)
        })
    };
    let serial = ThreadPool::serial();
    let threaded = ThreadPool::auto(0);
    assert_eq!(
        stage(&serial),
        stage(&threaded),
        "threaded grad+compress stage must be bitwise-identical to serial"
    );
    let m1 = b.bench(&format!("grad+compress stage n={nw} threads=1"), || {
        Bencher::black_box(stage(&serial));
    });
    let mn = b.bench(
        &format!("grad+compress stage n={nw} threads={}", threaded.threads()),
        || {
            Bencher::black_box(stage(&threaded));
        },
    );
    let speedup = m1.mean_secs() / mn.mean_secs();
    println!(
        "grad+compress stage speedup: {speedup:.2}x with {} threads on {} cores \
         (target >=1.5x on >=4 cores)",
        threaded.threads(),
        ThreadPool::available()
    );

    // Pooled AR-Topk (VAR computes every worker's top-k, so it parallelizes).
    let mut art_var =
        ArTopk::new(SelectionPolicy::Var, ArFlavor::Ring).with_pool(threaded.clone());
    b.bench(&format!("artopk VAR exchange n={nw} threads={}", threaded.threads()), || {
        let mut ef: Vec<EfState> = (0..nw).map(|_| EfState::new(wdim)).collect();
        Bencher::black_box(art_var.exchange(&base, &mut ef, 0.01, 0, link));
    });

    // ------------------------------------------------------------------
    // Spawn-vs-park (ISSUE 6 tentpole): many TINY regions, where thread
    // spawn/join cost dominates the old per-region scoped engine. The
    // persistent pool parks its workers between regions, so the per-region
    // cost is one condvar wake instead of `threads` spawns + joins.
    // Outputs are pinned bitwise against both the scoped reference and a
    // serial run; the >=1.5x speedup is a soft assert (unmeasurable on
    // single-core hosts, where the persistent pool runs regions inline).
    // ------------------------------------------------------------------
    let regions = if fast { 50 } else { 400 };
    let tiny = &base; // nw small per-worker slices, reused as tiny tasks
    let tiny_work = |w: usize| -> f32 {
        let s: f32 = tiny[w].iter().take(512).sum();
        s * 1.000123
    };
    let park_run = |pool: &ThreadPool| -> Vec<f32> {
        let mut acc = vec![0.0f32; nw];
        for _ in 0..regions {
            let r = pool.map(nw, tiny_work);
            for (a, v) in acc.iter_mut().zip(&r) {
                *a += v;
            }
        }
        acc
    };
    let spawn_run = || -> Vec<f32> {
        let mut acc = vec![0.0f32; nw];
        for _ in 0..regions {
            let r = scoped_map(threaded.threads(), nw, tiny_work);
            for (a, v) in acc.iter_mut().zip(&r) {
                *a += v;
            }
        }
        acc
    };
    let park_out = park_run(&threaded);
    assert_eq!(
        park_out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        spawn_run().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "persistent pool must be bitwise-identical to the scoped-spawn engine"
    );
    assert_eq!(
        park_out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        park_run(&serial).iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "persistent pool must be bitwise-identical to a serial run"
    );
    let m_spawn = b.bench(&format!("spawn-per-region {regions} tiny regions"), || {
        Bencher::black_box(spawn_run());
    });
    let m_park = b.bench(&format!("parked-pool      {regions} tiny regions"), || {
        Bencher::black_box(park_run(&threaded));
    });
    let park_speedup = m_spawn.mean_secs() / m_park.mean_secs();
    if park_speedup >= 1.5 {
        println!("spawn-vs-park speedup: {park_speedup:.2}x (target >=1.5x: OK)");
    } else {
        println!(
            "WARNING: spawn-vs-park speedup {park_speedup:.2}x below the 1.5x target \
             on this host ({} cores) — soft assert, bitwise equality held",
            ThreadPool::available()
        );
    }

    // ------------------------------------------------------------------
    // Fresh-vs-arena: one AG-path compress step (error-feed + top-k select
    // + residual update), allocating fresh buffers each step vs reusing
    // the per-worker arenas (`error_fed_into` / `compress_into` /
    // `update_swap`). The two cycles are pinned bitwise over several
    // steps before timing; steady-state allocation is what differs.
    // ------------------------------------------------------------------
    let layout = Layout::single(wdim);
    let cr = 0.01;
    {
        // Bitwise pin: run both cycles side by side for 5 steps.
        let mut ef_fresh = EfState::new(wdim);
        let mut ef_arena = EfState::new(wdim);
        let mut c_fresh = TopK::with_quickselect();
        let mut c_arena = TopK::with_quickselect();
        let mut g_e = Vec::new();
        let mut part = SparseGrad::default();
        for step in 0..5 {
            let g_s = &base[step % nw];
            let ge_fresh = ef_fresh.error_fed(g_s);
            let sp = c_fresh.compress(&ge_fresh, cr, &layout);
            ef_fresh.update(ge_fresh, &sp);
            ef_arena.error_fed_into(g_s, &mut g_e);
            c_arena.compress_into(&g_e, cr, &layout, &mut part);
            ef_arena.update_swap(&mut g_e, &part);
            assert_eq!(sp.indices, part.indices, "step {step}: arena indices");
            assert_eq!(
                sp.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                part.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "step {step}: arena values"
            );
            assert_eq!(
                ef_fresh.residual.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                ef_arena.residual.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "step {step}: arena residual"
            );
        }
    }
    let mut ef_fresh = EfState::new(wdim);
    let mut c_fresh = TopK::with_quickselect();
    let m_fresh = b.bench(&format!("compress step fresh-alloc G={wdim}"), || {
        let ge = ef_fresh.error_fed(&base[0]);
        let sp = c_fresh.compress(&ge, cr, &layout);
        ef_fresh.update(Bencher::black_box(ge), &sp);
    });
    let mut ef_arena = EfState::new(wdim);
    let mut c_arena = TopK::with_quickselect();
    let mut g_e = Vec::new();
    let mut part = SparseGrad::default();
    let m_arena = b.bench(&format!("compress step arena-reuse G={wdim}"), || {
        ef_arena.error_fed_into(&base[0], &mut g_e);
        c_arena.compress_into(&g_e, cr, &layout, &mut part);
        ef_arena.update_swap(&mut g_e, Bencher::black_box(&part));
    });
    println!(
        "fresh-vs-arena compress step: {:.2}x (allocation savings; informational)",
        m_fresh.mean_secs() / m_arena.mean_secs()
    );

    // ------------------------------------------------------------------
    // Kernel layer (ISSUE 10 tentpole): scalar reference vs chunked
    // kernel, per primitive. Bitwise equality is a HARD assert — the
    // elementwise kernels against the verbatim old loops, the lane-split
    // reductions against their own strided scalar definition (the pinned
    // crate reduction policy). The speedup is printed per primitive and
    // soft-checked (>=1.3x) on multi-core hosts only: on throttled
    // single-core CI boxes neither side vectorizes predictably.
    // ------------------------------------------------------------------
    let kres = vec![0.01f32; dim];
    let mut kspeed: Vec<(&str, f64)> = Vec::new();

    // add_into — the fused error-feed sum (old loop: clear + extend(zip map)).
    let mut s_sum: Vec<f32> = Vec::with_capacity(dim);
    let mut k_sum: Vec<f32> = Vec::new();
    s_sum.clear();
    s_sum.extend(g.iter().zip(&kres).map(|(a, r)| a + r));
    kernels::add_into(&g, &kres, &mut k_sum);
    assert_eq!(bits(&s_sum), bits(&k_sum), "kernels add_into: bitwise vs scalar");
    let ms = b.bench(&format!("kernels add_into scalar   G={dim}"), || {
        s_sum.clear();
        s_sum.extend(g.iter().zip(&kres).map(|(a, r)| a + r));
        Bencher::black_box(&s_sum);
    });
    let mk = b.bench(&format!("kernels add_into chunked  G={dim}"), || {
        kernels::add_into(&g, &kres, &mut k_sum);
        Bencher::black_box(&k_sum);
    });
    kspeed.push(("add_into", ms.mean_secs() / mk.mean_secs()));

    // error_feed_abs — one fused pass vs the two passes it replaces.
    let mut s_mag: Vec<f32> = Vec::with_capacity(dim);
    let mut k_ge: Vec<f32> = Vec::new();
    let mut k_mag: Vec<f32> = Vec::new();
    s_sum.clear();
    s_sum.extend(g.iter().zip(&kres).map(|(a, r)| a + r));
    s_mag.clear();
    s_mag.extend(s_sum.iter().map(|v| v.abs()));
    kernels::error_feed_abs_into(&g, &kres, &mut k_ge, &mut k_mag);
    assert_eq!(bits(&s_sum), bits(&k_ge), "kernels error_feed_abs: g_e bitwise");
    assert_eq!(bits(&s_mag), bits(&k_mag), "kernels error_feed_abs: mag bitwise");
    let ms = b.bench(&format!("kernels error_feed_abs scalar   G={dim}"), || {
        s_sum.clear();
        s_sum.extend(g.iter().zip(&kres).map(|(a, r)| a + r));
        s_mag.clear();
        s_mag.extend(s_sum.iter().map(|v| v.abs()));
        Bencher::black_box((&s_sum, &s_mag));
    });
    let mk = b.bench(&format!("kernels error_feed_abs chunked  G={dim}"), || {
        kernels::error_feed_abs_into(&g, &kres, &mut k_ge, &mut k_mag);
        Bencher::black_box((&k_ge, &k_mag));
    });
    kspeed.push(("error_feed_abs", ms.mean_secs() / mk.mean_secs()));

    // sq_norm / dot — lane-split f64 reductions, pinned against the
    // strided-loop statement of the same definition.
    assert_eq!(
        ref_sq_norm_strided(&g).to_bits(),
        kernels::sq_norm_lanes(&g).to_bits(),
        "kernels sq_norm_lanes: bitwise vs strided definition"
    );
    let ms = b.bench(&format!("kernels sq_norm scalar   G={dim}"), || {
        Bencher::black_box(ref_sq_norm_strided(&g));
    });
    let mk = b.bench(&format!("kernels sq_norm chunked  G={dim}"), || {
        Bencher::black_box(kernels::sq_norm_lanes(&g));
    });
    kspeed.push(("sq_norm", ms.mean_secs() / mk.mean_secs()));

    assert_eq!(
        ref_dot_strided(&g, &k_ge).to_bits(),
        kernels::dot_lanes(&g, &k_ge).to_bits(),
        "kernels dot_lanes: bitwise vs strided definition"
    );
    let ms = b.bench(&format!("kernels dot scalar   G={dim}"), || {
        Bencher::black_box(ref_dot_strided(&g, &k_ge));
    });
    let mk = b.bench(&format!("kernels dot chunked  G={dim}"), || {
        Bencher::black_box(kernels::dot_lanes(&g, &k_ge));
    });
    kspeed.push(("dot", ms.mean_secs() / mk.mean_secs()));

    // abs_pairs — the (|g[i]|, i) builder feeding quickselect.
    let mut s_pairs: Vec<(f32, u32)> = Vec::with_capacity(dim);
    let mut k_pairs: Vec<(f32, u32)> = Vec::new();
    s_pairs.clear();
    s_pairs.extend(g.iter().enumerate().map(|(i, &v)| (v.abs(), i as u32)));
    kernels::abs_pairs_into(&g, &mut k_pairs);
    assert_eq!(pair_bits(&s_pairs), pair_bits(&k_pairs), "kernels abs_pairs: bitwise");
    let ms = b.bench(&format!("kernels abs_pairs scalar   G={dim}"), || {
        s_pairs.clear();
        s_pairs.extend(g.iter().enumerate().map(|(i, &v)| (v.abs(), i as u32)));
        Bencher::black_box(&s_pairs);
    });
    let mk = b.bench(&format!("kernels abs_pairs chunked  G={dim}"), || {
        kernels::abs_pairs_into(&g, &mut k_pairs);
        Bencher::black_box(&k_pairs);
    });
    kspeed.push(("abs_pairs", ms.mean_secs() / mk.mean_secs()));

    // threshold_count / threshold_filter — the sampled-top-k filter pass.
    // Threshold = the k-th magnitude, so the filter keeps ~k of dim.
    let t_i = *topk_indices(&g, k).last().expect("k >= 1");
    let tau = (g[t_i as usize].abs(), t_i);
    let s_count = g.iter().filter(|v| v.abs() > tau.0).count();
    assert_eq!(
        s_count,
        kernels::threshold_count(&g, tau.0),
        "kernels threshold_count: exact count vs scalar"
    );
    let ms = b.bench(&format!("kernels threshold_count scalar   G={dim}"), || {
        Bencher::black_box(g.iter().filter(|v| v.abs() > tau.0).count());
    });
    let mk = b.bench(&format!("kernels threshold_count chunked  G={dim}"), || {
        Bencher::black_box(kernels::threshold_count(&g, tau.0));
    });
    kspeed.push(("threshold_count", ms.mean_secs() / mk.mean_secs()));

    // Scalar filter reference: push-if under the `mag_desc_idx_asc`
    // total order (descending magnitude, NaN smallest, ties by ascending
    // index), inlined here via the public `nan_min_cmp_f32` since the
    // comparator itself is crate-private: keep p iff p ranks at-or-before
    // the threshold pair.
    let keep = |p: (f32, u32)| -> bool {
        nan_min_cmp_f32(tau.0, p.0).then_with(|| p.1.cmp(&tau.1)) != Ordering::Greater
    };
    s_pairs.clear();
    for (i, &v) in g.iter().enumerate() {
        let p = (v.abs(), i as u32);
        if keep(p) {
            s_pairs.push(p);
        }
    }
    kernels::threshold_filter_into(&g, tau, &mut k_pairs);
    assert_eq!(
        pair_bits(&s_pairs),
        pair_bits(&k_pairs),
        "kernels threshold_filter: bitwise vs comparator push-if loop"
    );
    let ms = b.bench(&format!("kernels threshold_filter scalar   G={dim}"), || {
        s_pairs.clear();
        for (i, &v) in g.iter().enumerate() {
            let p = (v.abs(), i as u32);
            if keep(p) {
                s_pairs.push(p);
            }
        }
        Bencher::black_box(&s_pairs);
    });
    let mk = b.bench(&format!("kernels threshold_filter chunked  G={dim}"), || {
        kernels::threshold_filter_into(&g, tau, &mut k_pairs);
        Bencher::black_box(&k_pairs);
    });
    kspeed.push(("threshold_filter", ms.mean_secs() / mk.mean_secs()));

    // scatter_zero — residual zeroing at the selected (sorted) indices.
    let zidx: Vec<u32> = (0..k).map(|i| (i * (dim / k)) as u32).collect();
    let mut s_x = g.clone();
    let mut k_x = g.clone();
    for &i in &zidx {
        s_x[i as usize] = 0.0;
    }
    kernels::scatter_zero(&mut k_x, &zidx);
    assert_eq!(bits(&s_x), bits(&k_x), "kernels scatter_zero: bitwise");
    let ms = b.bench(&format!("kernels scatter_zero scalar   k={k}"), || {
        for &i in &zidx {
            s_x[i as usize] = 0.0;
        }
        Bencher::black_box(&s_x);
    });
    let mk = b.bench(&format!("kernels scatter_zero chunked  k={k}"), || {
        kernels::scatter_zero(&mut k_x, &zidx);
        Bencher::black_box(&k_x);
    });
    kspeed.push(("scatter_zero", ms.mean_secs() / mk.mean_secs()));

    println!("kernel layer speedups (scalar reference -> chunked kernel):");
    let mut k_min = f64::INFINITY;
    let mut k_min_name = "";
    for &(name, s) in &kspeed {
        println!("  {name:<18} {s:5.2}x");
        if s < k_min {
            k_min = s;
            k_min_name = name;
        }
    }
    if ThreadPool::available() >= 2 && k_min < 1.3 {
        println!(
            "WARNING: kernel {k_min_name} speedup {k_min:.2}x below the 1.3x target \
             on this host ({} cores) — soft assert, bitwise equality held",
            ThreadPool::available()
        );
    }

    // Machine-readable record for the regression harness: verify.sh fails
    // if this file is missing after the smoke-mode bench stage.
    let json_path = std::path::Path::new("BENCH_hotpath.json");
    b.write_json("hotpath", json_path).expect("write BENCH_hotpath.json");
    println!(
        "\n{} measurements recorded (see EXPERIMENTS.md §Perf); wrote {}.",
        b.results.len(),
        json_path.display()
    );
}
