//! In-memory checkpoint/restore (§3-E): the MOO controller snapshots the
//! full training state before probing candidate CRs and restores it after,
//! so exploration can't degrade the model. System-memory only — the paper
//! explicitly avoids disk round-trips here.

/// A full training-state snapshot.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
    pub residuals: Vec<Vec<f32>>,
    pub step: u64,
    pub clock: f64,
}

impl Checkpoint {
    /// Approximate heap footprint (bytes) — exploration keeps exactly one.
    pub fn size_bytes(&self) -> usize {
        4 * (self.params.len()
            + self.momentum.len()
            + self.residuals.iter().map(|r| r.len()).sum::<usize>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_accounting() {
        let c = Checkpoint {
            params: vec![0.0; 10],
            momentum: vec![0.0; 10],
            residuals: vec![vec![0.0; 10]; 4],
            step: 3,
            clock: 1.0,
        };
        assert_eq!(c.size_bytes(), 4 * (10 + 10 + 40));
    }
}
