//! α-β network simulator.
//!
//! The paper's testbed shapes a real 8-GPU cluster with linux `tc` (netem
//! latency + htb bandwidth). Here the *link* is simulated: every collective
//! really moves data between in-process worker buffers, and its wall-time is
//! charged from the same α-β cost algebra the paper validates against
//! hardware (Tables I/II/VI).
//!
//! * [`cost_model`] — closed-form collective costs (Table I, Eqn 4) and the
//!   switching heuristics (Eqn 5).
//! * [`schedule`] — time-varying (α, β) schedules incl. the paper's C1/C2
//!   (Fig 6), plus jitter and congestion-episode models.
//! * [`probe`] — the iperf/traceroute analogue: noisy observations of the
//!   current link, with change detection.

pub mod cost_model;
pub mod probe;
pub mod schedule;

/// Virtual wall clock (seconds). The trainer advances it with compute,
/// compression and (simulated) communication time.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn advance(&mut self, seconds: f64) {
        debug_assert!(seconds >= 0.0, "negative time advance {seconds}");
        self.now += seconds;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_monotonically() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        c.advance(0.5);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }
}
