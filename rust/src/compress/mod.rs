//! Gradient compressors with error feedback (paper §2-C, Eqn 2).
//!
//! All compressors produce a [`SparseGrad`] from an error-fed gradient.
//! Residual bookkeeping (Eqn 2) lives in [`EfState`], shared by every
//! compressor and by AR-Topk.  Compression *time* is measured for real
//! (these run on the actual coordinator CPU — Fig 2 regenerates from these
//! measurements); communication time is simulated by the collectives.

pub mod gain;
pub mod lwtopk;
pub mod mstopk;
pub mod randomk;
pub mod sampledk;
pub mod topk;

pub use gain::GainTracker;
pub use lwtopk::LwTopk;
pub use mstopk::MsTopk;
pub use randomk::RandomK;
pub use sampledk::SampledK;
pub use topk::{select_into, topk_indices, SelectBackend, SelectScratch, TopK};

use crate::tensor::{kernels, Layout};
use anyhow::{bail, Result};

/// A compressed gradient: `k` (index, value) pairs over a dense vector.
///
/// `Default` (the empty gradient) exists so arena-holding call sites can
/// `std::mem::take` a worker's part for an owned hand-off (e.g. into a
/// collective) and put it back afterwards without reallocating.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SparseGrad {
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
    pub dense_len: usize,
}

impl SparseGrad {
    pub fn k(&self) -> usize {
        debug_assert_eq!(self.indices.len(), self.values.len());
        self.indices.len()
    }

    /// Wire size in bytes for AG-style exchange (values + indices).
    pub fn wire_bytes(&self) -> usize {
        8 * self.k()
    }

    /// Scatter into a fresh dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dense_len];
        kernels::scatter_add(&mut out, &self.indices, &self.values);
        out
    }

    /// Sum of squared values (the gain numerator ||g_c||^2), under the
    /// crate's lane-split reduction policy.
    pub fn sq_norm(&self) -> f64 {
        kernels::sq_norm_lanes(&self.values)
    }
}

/// Common interface: compress an (error-fed) gradient at ratio `cr`.
///
/// `Send` so per-worker compressor instances can run on the trainer's
/// worker threads (each thread gets exclusive `&mut` access to its own
/// instance — see the AG-compress strategy’s `ag_exchange` and DESIGN.md §7).
pub trait Compressor: Send {
    fn name(&self) -> &'static str;
    /// `layout` supplies layer boundaries (used by LWTopk; others ignore it).
    fn compress(&mut self, g: &[f32], cr: f64, layout: &Layout) -> SparseGrad;

    /// Compress into a caller-owned [`SparseGrad`] arena, reusing its
    /// `indices`/`values` allocations across steps. MUST be bitwise
    /// equivalent to `*out = self.compress(g, cr, layout)` (the default —
    /// property tests in `sampledk.rs` pin the overriding impls); only the
    /// allocation behaviour may differ.
    fn compress_into(&mut self, g: &[f32], cr: f64, layout: &Layout, out: &mut SparseGrad) {
        *out = self.compress(g, cr, layout);
    }
}

/// Compressor selection by name (config/CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressorKind {
    TopK,
    LwTopk,
    MsTopk,
    RandomK,
    /// Sampled-threshold top-k with exact-k repair: bitwise-identical
    /// output to [`CompressorKind::TopK`], cheaper selection (see
    /// `compress/sampledk.rs` for the repair contract).
    SampledK,
}

impl CompressorKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "topk" => CompressorKind::TopK,
            "lwtopk" => CompressorKind::LwTopk,
            "mstopk" => CompressorKind::MsTopk,
            "randomk" => CompressorKind::RandomK,
            "sampledk" => CompressorKind::SampledK,
            _ => bail!("unknown compressor `{s}` (topk|lwtopk|mstopk|randomk|sampledk)"),
        })
    }

    pub fn build(&self, seed: u64) -> Box<dyn Compressor> {
        match self {
            CompressorKind::TopK => Box::new(TopK::new()),
            CompressorKind::LwTopk => Box::new(LwTopk::new()),
            CompressorKind::MsTopk => Box::new(MsTopk::new(25)),
            CompressorKind::RandomK => Box::new(RandomK::new(seed)),
            CompressorKind::SampledK => Box::new(SampledK::new()),
        }
    }
}

/// Error-feedback state for one worker (Eqn 2): residuals accumulate the
/// gradient mass that compression dropped.
#[derive(Debug, Clone)]
pub struct EfState {
    pub residual: Vec<f32>,
}

impl EfState {
    pub fn new(dim: usize) -> Self {
        EfState { residual: vec![0.0; dim] }
    }

    /// `g_e = g + residual` (Eqn 2a). Delegates through the `add_into`
    /// kernel, which pre-reserves `g.len()` — the convenience path no
    /// longer grows a zero-capacity Vec through `extend`.
    pub fn error_fed(&self, g: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.error_fed_into(g, &mut out);
        out
    }

    /// [`EfState::error_fed`] into a caller-owned staging buffer (fully
    /// overwritten, so no state leaks across steps) — paired with
    /// [`EfState::update_swap`] this makes the whole Eqn-2 cycle
    /// allocation-free in steady state.
    pub fn error_fed_into(&self, g: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(g.len(), self.residual.len());
        kernels::add_into(g, &self.residual, out);
    }

    /// Fused Eqn-2a: one pass filling both `g_e = g + residual` and its
    /// magnitude buffer `mag[i] = |g_e[i]|`, so top-k selection can run
    /// over precomputed magnitudes without a second sweep (see
    /// `kernels::error_feed_abs_into` and `topk::select_mags_into`).
    pub fn error_fed_abs_into(&self, g: &[f32], g_e: &mut Vec<f32>, mag: &mut Vec<f32>) {
        debug_assert_eq!(g.len(), self.residual.len());
        kernels::error_feed_abs_into(g, &self.residual, g_e, mag);
    }

    /// Update residual after compressing `g_e` into `sparse`
    /// (Eqn 2b: residual = g_e - g_c). Consumes `g_e` to avoid a copy.
    pub fn update(&mut self, mut g_e: Vec<f32>, sparse: &SparseGrad) {
        self.update_swap(&mut g_e, sparse);
    }

    /// [`EfState::update`] for arena call sites: zero the sent coordinates
    /// in the staged `g_e` buffer, then swap it with the residual — the
    /// outgoing residual Vec becomes the caller's staging buffer for the
    /// NEXT step. Bitwise identical to `update(g_e.clone(), sparse)`; zero
    /// allocations.
    pub fn update_swap(&mut self, g_e: &mut Vec<f32>, sparse: &SparseGrad) {
        debug_assert_eq!(g_e.len(), self.residual.len());
        kernels::scatter_zero(g_e, &sparse.indices);
        std::mem::swap(&mut self.residual, g_e);
    }

    /// residual update for AR-Topk's broadcast-index path: the *sent*
    /// entries are exactly the broadcast indices, regardless of the local
    /// top-k (Alg 1 lines 15-16).
    pub fn update_at_indices(&mut self, mut g_e: Vec<f32>, indices: &[u32]) {
        self.update_at_indices_swap(&mut g_e, indices);
    }

    /// Swap-based [`EfState::update_at_indices`] (same contract as
    /// [`EfState::update_swap`]).
    pub fn update_at_indices_swap(&mut self, g_e: &mut Vec<f32>, indices: &[u32]) {
        debug_assert_eq!(g_e.len(), self.residual.len());
        kernels::scatter_zero(g_e, indices);
        std::mem::swap(&mut self.residual, g_e);
    }

    pub fn reset(&mut self) {
        self.residual.iter_mut().for_each(|r| *r = 0.0);
    }
}

/// Exact top-k count for a compression ratio: `ceil(cr * len)`, min 1 —
/// except an EMPTY gradient, where the only valid k is 0 (`clamp(1, 0)`
/// would panic with `min > max`).
pub fn k_for(cr: f64, len: usize) -> usize {
    if len == 0 {
        return 0;
    }
    ((cr * len as f64).ceil() as usize).clamp(1, len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_grad_roundtrip() {
        let s = SparseGrad { indices: vec![1, 3], values: vec![2.0, -4.0], dense_len: 5 };
        assert_eq!(s.k(), 2);
        assert_eq!(s.wire_bytes(), 16);
        assert_eq!(s.to_dense(), vec![0.0, 2.0, 0.0, -4.0, 0.0]);
        assert!((s.sq_norm() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn ef_state_eqn2() {
        let mut ef = EfState::new(4);
        ef.residual = vec![0.5, 0.0, -0.5, 0.0];
        let g = vec![1.0, 2.0, 3.0, 4.0];
        let g_e = ef.error_fed(&g);
        assert_eq!(g_e, vec![1.5, 2.0, 2.5, 4.0]);
        let sparse = SparseGrad { indices: vec![1, 3], values: vec![2.0, 4.0], dense_len: 4 };
        ef.update(g_e, &sparse);
        // Sent coordinates zeroed; dropped mass kept.
        assert_eq!(ef.residual, vec![1.5, 0.0, 2.5, 0.0]);
    }

    #[test]
    fn k_for_bounds() {
        assert_eq!(k_for(0.1, 100), 10);
        assert_eq!(k_for(0.001, 100), 1); // ceil + min 1
        assert_eq!(k_for(1.0, 7), 7);
        assert_eq!(k_for(0.0, 7), 1); // never zero
        assert_eq!(k_for(0.015, 1000), 15);
        // Regression: len == 0 used to hit clamp(1, 0) and panic.
        assert_eq!(k_for(0.1, 0), 0);
        assert_eq!(k_for(1.0, 0), 0);
    }

    #[test]
    fn empty_gradient_compresses_to_empty() {
        // k_for(_, 0) == 0 must carry through every compressor without a
        // panic and produce the empty SparseGrad.
        let layout = Layout::single(0);
        let g: Vec<f32> = vec![];
        for kind in [
            CompressorKind::TopK,
            CompressorKind::MsTopk,
            CompressorKind::RandomK,
            CompressorKind::SampledK,
        ] {
            let mut c = kind.build(7);
            let s = c.compress(&g, 0.1, &layout);
            assert_eq!(s.k(), 0, "{}", c.name());
            assert_eq!(s.dense_len, 0, "{}", c.name());
        }
    }

    #[test]
    fn error_fed_abs_matches_separate_passes() {
        let mut ef = EfState::new(4);
        ef.residual = vec![0.5, 0.0, -3.5, 0.0];
        let g = vec![1.0, -2.0, 3.0, 4.0];
        let (mut g_e, mut mag) = (Vec::new(), Vec::new());
        ef.error_fed_abs_into(&g, &mut g_e, &mut mag);
        assert_eq!(g_e, ef.error_fed(&g));
        let want: Vec<f32> = g_e.iter().map(|v| v.abs()).collect();
        assert_eq!(mag, want);
    }

    #[test]
    fn kind_parse_and_build() {
        for (s, n) in [
            ("topk", "topk"),
            ("lwtopk", "lwtopk"),
            ("mstopk", "mstopk"),
            ("randomk", "randomk"),
        ] {
            let k = CompressorKind::parse(s).unwrap();
            assert_eq!(k.build(0).name(), n);
        }
        assert!(CompressorKind::parse("bogus").is_err());
    }
}
